//! Model replica: the deterministic server core and its pool binding.
//!
//! A replica is one copy of the model able to execute batches. All
//! numerics live here; the scheduler (sibling module) only decides
//! *which* requests form a batch and *which* replica runs it — both pure
//! functions of ticket numbers, so the split cannot affect bits.

use super::tower::ModelTower;
use crate::baseline::{baseline_matmul, PlatformProfile};
use crate::bench_harness::bench;
use crate::coordinator::hashing::hash_tensor;
use crate::tensor::microkernel::{gemm_packed_into, pack_b_panels, packed_b_len};
use crate::tensor::pool::global_pool;
use crate::tensor::{scratch_f32, PoolHandle, Tensor, WorkerPool};
use crate::{Error, Result};
use std::sync::Arc;

/// Reject a request whose row length cannot feed the weight matrix —
/// shared by the repro and baseline batching loops *and* the scheduler's
/// submit gate, so malformed input yields the same error on every path
/// (never a panic).
pub(super) fn check_request(r: &Tensor, d_in: usize) -> Result<()> {
    if r.numel() != d_in {
        return Err(Error::shape(format!(
            "serve: request has {} elements, weights want {d_in}",
            r.numel()
        )));
    }
    Ok(())
}

/// A toy model server: logits = x · W (+ per-row softmax left to client).
pub struct DeterministicServer {
    /// Weights (in, out). Read-only after construction — the packed
    /// panel copy below is derived from it exactly once.
    pub weights: Tensor,
    /// Max batch per dispatch.
    pub max_batch: usize,
    /// `weights` pre-packed into microkernel B panels (layout-only,
    /// built once in [`Self::new`]), so the serve hot path never
    /// re-packs the immutable weight matrix per call.
    packed_w: Vec<f32>,
    /// Content address of `weights` (`hash_tensor`), computed once —
    /// the [`ModelTower::weights_hash`] the scheduler embeds in cache
    /// keys and log entries.
    weights_hash: String,
}

/// Outcome of replaying the same requests under different batch mixes.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Requests checked.
    pub requests: usize,
    /// Requests whose bits changed with batch composition (RepDL path).
    pub repro_mismatches: usize,
    /// Requests whose bits changed with batch composition (baseline).
    pub baseline_mismatches: usize,
}

/// Serving throughput measurement (see
/// [`DeterministicServer::throughput_report`]).
#[derive(Clone, Debug)]
pub struct ServeThroughput {
    /// Requests per replay.
    pub requests: usize,
    /// Median requests/second over the measured replays.
    pub req_per_s: f64,
    /// Median time for one full-queue replay, nanoseconds.
    pub median_ns: f64,
}

impl DeterministicServer {
    /// New server. Fallible: non-rank-2 weights are a shape *error* (the
    /// old constructor indexed `dims()[0]`/`[1]` unchecked and panicked
    /// — same error-not-panic policy as [`check_request`]). Packs the
    /// weight matrix into microkernel B panels once, up front
    /// (layout-only — cannot change any output bit).
    pub fn new(weights: Tensor, max_batch: usize) -> Result<Self> {
        let d = weights.dims();
        if d.len() != 2 {
            return Err(Error::shape(format!(
                "serve: weights must be rank 2 (in, out), got {d:?}"
            )));
        }
        let (d_in, d_out) = (d[0], d[1]);
        let mut packed_w = vec![0.0f32; packed_b_len(d_in, d_out)];
        pack_b_panels(global_pool(), weights.data(), d_in, d_out, &mut packed_w);
        let weights_hash = hash_tensor(&weights);
        Ok(DeterministicServer { weights, max_batch, packed_w, weights_hash })
    }

    /// Input feature count (weight rows).
    pub fn d_in(&self) -> usize {
        self.weights.dims()[0]
    }

    /// Output feature count (weight columns).
    pub fn d_out(&self) -> usize {
        self.weights.dims()[1]
    }

    /// Content address of the weight matrix, computed at construction.
    pub fn weights_hash(&self) -> &str {
        &self.weights_hash
    }

    /// Process a queue in arrival order, batching up to `max_batch`.
    /// Returns one output row per request.
    pub fn process_repro(&self, queue: &[Tensor]) -> Result<Vec<Tensor>> {
        self.process_repro_in(global_pool(), queue)
    }

    /// [`Self::process_repro`] with every batch GEMM dispatched on an
    /// explicit [`WorkerPool`] — the serving hot path shares one
    /// persistent pool across all requests instead of spawning threads
    /// per batch, and runs the packed register-tiled microkernel
    /// against the weight panels **packed once at construction**, with
    /// scratch-arena staging/output buffers (reused across calls), so a
    /// steady-state serve loop allocates only the per-request output
    /// rows it must return. Bit-identical to `matmul(x, W)` row for row
    /// and for any pool size (asserted in tests and the
    /// `pool_invariance` suite).
    pub fn process_repro_in(&self, pool: &WorkerPool, queue: &[Tensor]) -> Result<Vec<Tensor>> {
        let d_in = self.d_in();
        let d_out = self.d_out();
        let mb = self.max_batch.max(1);
        let packed = &self.packed_w; // packed once at construction
        let mut stage = scratch_f32(mb * d_in);
        let mut ybuf = scratch_f32(mb * d_out);
        let mut outs = Vec::with_capacity(queue.len());
        for chunk in queue.chunks(mb) {
            let x = &mut stage[..chunk.len() * d_in];
            for (i, r) in chunk.iter().enumerate() {
                check_request(r, d_in)?;
                x[i * d_in..(i + 1) * d_in].copy_from_slice(r.data());
            }
            let y = &mut ybuf[..chunk.len() * d_out];
            gemm_packed_into(pool, x, chunk.len(), d_in, packed, d_out, None, false, y);
            for i in 0..chunk.len() {
                outs.push(Tensor::from_vec(
                    &[d_out],
                    y[i * d_out..(i + 1) * d_out].to_vec(),
                )?);
            }
        }
        Ok(outs)
    }

    /// Baseline path under a platform profile (size-dispatching kernels).
    pub fn process_baseline(
        &self,
        queue: &[Tensor],
        p: &PlatformProfile,
    ) -> Result<Vec<Tensor>> {
        self.process_with(queue, |x| baseline_matmul(x, &self.weights, p))
    }

    fn process_with(
        &self,
        queue: &[Tensor],
        f: impl Fn(&Tensor) -> Result<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let d_in = self.d_in();
        let d_out = self.d_out();
        let mut outs = Vec::with_capacity(queue.len());
        for chunk in queue.chunks(self.max_batch.max(1)) {
            let mut x = Tensor::zeros(&[chunk.len(), d_in]);
            for (i, r) in chunk.iter().enumerate() {
                check_request(r, d_in)?; // same error as the repro path
                x.data_mut()[i * d_in..(i + 1) * d_in].copy_from_slice(r.data());
            }
            let y = f(&x)?;
            for i in 0..chunk.len() {
                outs.push(Tensor::from_vec(
                    &[d_out],
                    y.data()[i * d_out..(i + 1) * d_out].to_vec(),
                )?);
            }
        }
        Ok(outs)
    }

    /// Measure serving throughput (requests/second) through an explicit
    /// pool: the whole queue is replayed `samples` times via
    /// [`Self::process_repro_in`] and the median per-replay time is
    /// converted to req/s. Prints one `bench_harness` row.
    pub fn throughput_report(
        &self,
        pool: &WorkerPool,
        queue: &[Tensor],
        samples: usize,
    ) -> Result<ServeThroughput> {
        // Validate shapes once up front so the measured closure cannot
        // fail (bench requires infallible work).
        self.process_repro_in(pool, queue)?;
        let label = format!("serve {} reqs, pool={} lanes", queue.len(), pool.lanes());
        let stats = bench(&label, samples.max(1), || {
            self.process_repro_in(pool, queue).unwrap()
        });
        Ok(ServeThroughput {
            requests: queue.len(),
            req_per_s: stats.per_sec(queue.len()),
            median_ns: stats.median_ns,
        })
    }

    /// Replay the same requests under several batch sizes and count
    /// per-request bit mismatches for both numerics paths.
    pub fn batch_invariance_report(
        &self,
        queue: &[Tensor],
        batch_sizes: &[usize],
        p: &PlatformProfile,
    ) -> Result<ServeReport> {
        let mut repro_all = Vec::new();
        let mut base_all = Vec::new();
        for &bs in batch_sizes {
            // same weights → same panels; clone them instead of repacking
            let s = DeterministicServer {
                weights: self.weights.clone(),
                max_batch: bs,
                packed_w: self.packed_w.clone(),
                weights_hash: self.weights_hash.clone(),
            };
            repro_all.push(s.process_repro(queue)?);
            base_all.push(s.process_baseline(queue, p)?);
        }
        let mut repro_mismatches = 0;
        let mut baseline_mismatches = 0;
        for r in 0..queue.len() {
            if repro_all.iter().any(|o| !o[r].bit_eq(&repro_all[0][r])) {
                repro_mismatches += 1;
            }
            if base_all.iter().any(|o| !o[r].bit_eq(&base_all[0][r])) {
                baseline_mismatches += 1;
            }
        }
        Ok(ServeReport { requests: queue.len(), repro_mismatches, baseline_mismatches })
    }
}

/// One scheduler shard: a [`ModelTower`] bound to the [`WorkerPool`]
/// its batches dispatch on. Both sides are shareable handles — several
/// replicas can serve the same `Arc`'d tower (one weight copy — for the
/// linear tower, one packed-panel copy; zero per-replica packing) and
/// can share one pool (concurrent dispatchers are supported by
/// [`WorkerPool`]) or own private pools; either choice is bit-neutral
/// because pool size never changes kernel bits (a tower contract,
/// DESIGN.md §9).
pub struct ServeReplica {
    tower: Arc<dyn ModelTower>,
    pool: PoolHandle,
}

impl ServeReplica {
    /// Bind a shared tower to a (shareable) pool handle. `Arc`s of
    /// concrete towers ([`DeterministicServer`],
    /// [`super::MlpTower`], [`super::TransformerTower`]) coerce here.
    pub fn new(tower: Arc<dyn ModelTower>, pool: PoolHandle) -> ServeReplica {
        ServeReplica { tower, pool }
    }

    /// The model tower this replica serves.
    pub fn tower(&self) -> &Arc<dyn ModelTower> {
        &self.tower
    }

    /// The pool this replica's batches dispatch on.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Execute one batch on this replica's pool (one output row per
    /// request, bit-identical for any pool size — the tower contract).
    /// Batch invariance is also what makes the audit path sound: the
    /// scheduler's `replay` re-executes logged requests as singleton
    /// batches here and may demand bit-equality with responses that were
    /// originally served from arbitrary batch compositions (or from the
    /// memo cache, which those compositions filled).
    pub fn process(&self, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        self.tower.forward_batch(&self.pool, batch)
    }

    /// [`Self::process`] with each request's admission ticket, so
    /// session-holding towers can key their KV stores by the scheduler's
    /// logical clock ([`ModelTower::forward_batch_ticketed`]). Towers
    /// without sessions ignore the tickets and this is exactly
    /// `process`. `tickets.len()` must equal `batch.len()`.
    pub fn process_ticketed(&self, batch: &[Tensor], tickets: &[u64]) -> Result<Vec<Tensor>> {
        if tickets.len() != batch.len() {
            return Err(Error::shape(format!(
                "serve: {} tickets for {} requests",
                tickets.len(),
                batch.len()
            )));
        }
        self.tower.forward_batch_ticketed(&self.pool, batch, tickets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    fn queue(n: usize, d: usize) -> Vec<Tensor> {
        (0..n)
            .map(|i| {
                let mut s = i as u64 + 1;
                Tensor::from_vec(
                    &[d],
                    (0..d)
                        .map(|_| {
                            s = s.wrapping_mul(6364136223846793005).wrapping_add(11);
                            (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 3.0
                        })
                        .collect(),
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn repro_path_is_batch_invariant() {
        let w = crate::rng::uniform_tensor(&[256, 8], -0.3, 0.3, 5);
        let srv = DeterministicServer::new(w, 16).unwrap();
        let q = queue(50, 256);
        let p = PlatformProfile::zoo()[4]; // gpu-warp32, size dispatch
        let rep = srv.batch_invariance_report(&q, &[1, 4, 16, 50], &p).unwrap();
        assert_eq!(rep.repro_mismatches, 0, "RepDL must be batch invariant");
        assert!(
            rep.baseline_mismatches > 0,
            "baseline unexpectedly invariant — dispatch simulation broken?"
        );
    }

    #[test]
    fn pooled_path_is_bit_identical_and_pool_size_invariant() {
        let w = crate::rng::uniform_tensor(&[64, 8], -0.3, 0.3, 6);
        let srv = DeterministicServer::new(w, 8).unwrap();
        let q = queue(21, 64);
        let global = srv.process_repro(&q).unwrap();
        for lanes in [1usize, 2, 5, 8] {
            let pool = WorkerPool::new(lanes);
            let got = srv.process_repro_in(&pool, &q).unwrap();
            for (a, b) in global.iter().zip(got.iter()) {
                assert!(a.bit_eq(b), "lanes={lanes}");
            }
        }
    }

    #[test]
    fn throughput_report_counts_requests() {
        let w = crate::rng::uniform_tensor(&[32, 4], -0.3, 0.3, 8);
        let srv = DeterministicServer::new(w, 16).unwrap();
        let q = queue(12, 32);
        let pool = WorkerPool::new(2);
        let t = srv.throughput_report(&pool, &q, 3).unwrap();
        assert_eq!(t.requests, 12);
        assert!(t.req_per_s > 0.0);
        assert!(t.median_ns > 0.0);
    }

    #[test]
    fn outputs_match_direct_compute() {
        let w = crate::rng::uniform_tensor(&[16, 4], -0.5, 0.5, 9);
        let srv = DeterministicServer::new(w.clone(), 3).unwrap();
        let q = queue(7, 16);
        let outs = srv.process_repro(&q).unwrap();
        for (r, o) in q.iter().zip(outs.iter()) {
            let x = r.reshape(&[1, 16]).unwrap();
            let want = matmul(&x, &w).unwrap();
            assert_eq!(o.data(), want.data());
        }
    }

    #[test]
    fn non_rank2_weights_error_instead_of_panicking() {
        for dims in [&[16][..], &[2, 3, 4][..], &[][..]] {
            let w = Tensor::zeros(dims);
            assert!(
                DeterministicServer::new(w, 8).is_err(),
                "rank-{} weights must be a shape error",
                dims.len()
            );
        }
    }

    #[test]
    fn replicas_share_one_server_and_one_pool() {
        let w = crate::rng::uniform_tensor(&[32, 4], -0.5, 0.5, 10);
        let server = Arc::new(DeterministicServer::new(w, 8).unwrap());
        let pool = WorkerPool::shared(3);
        let q = queue(9, 32);
        let want = server.process_repro(&q).unwrap();
        let r1 = ServeReplica::new(Arc::clone(&server), Arc::clone(&pool));
        let r2 = ServeReplica::new(Arc::clone(&server), pool);
        for rep in [&r1, &r2] {
            let got = rep.process(&q).unwrap();
            for (a, b) in want.iter().zip(got.iter()) {
                assert!(a.bit_eq(b));
            }
        }
    }
}
