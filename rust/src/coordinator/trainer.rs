//! Training loops with switchable numerics — the E1/E2/E8 engine.
//!
//! [`NumericsMode::Repro`] runs RepDL kernels; the other modes run the
//! conventional [`crate::baseline`] kernels under a simulated platform or
//! with simulated atomics — the experiment's control group. The MLP
//! trainer implements its forward/backward *manually* so the identical
//! mathematical graph runs under either numerics (only the kernels —
//! reduction order, libm, FMA — change, matching the paper's taxonomy).

use crate::baseline::{atomic_sum, baseline_matmul, baseline_softmax_rows, PlatformProfile};
use crate::coordinator::hashing::hash_params;
use crate::data::GaussianMixtureImages;
use crate::nn::softmax_rows;
use crate::rng::derive_seed;
use crate::tensor::{global_pool, matmul_in, sum_axis_in, Tensor, WorkerPool};
use crate::Result;
use std::sync::Arc;

/// Which numerics the trainer runs.
#[derive(Clone, Copy, Debug)]
pub enum NumericsMode {
    /// RepDL reproducible kernels.
    Repro,
    /// Conventional kernels under a simulated platform.
    Baseline(PlatformProfile),
    /// Conventional kernels + simulated atomic-order bias-gradient
    /// reduction (run-to-run non-deterministic).
    BaselineAtomic(PlatformProfile),
}

/// Trainer configuration (2-layer MLP on the synthetic image task).
#[derive(Clone, Copy, Debug)]
pub struct TrainerConfig {
    /// Input side (images are side×side).
    pub side: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Classes.
    pub classes: usize,
    /// Batch size.
    pub batch: usize,
    /// Steps.
    pub steps: usize,
    /// Learning rate.
    pub lr: f32,
    /// Base seed (init + data order).
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig { side: 8, hidden: 32, classes: 4, batch: 16, steps: 60, lr: 0.2, seed: 42 }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Loss at every step.
    pub loss_curve: Vec<f32>,
    /// SHA-256 of the final parameters.
    pub param_hash: String,
    /// Final parameters (w1, b1, w2, b2).
    pub params: Vec<Tensor>,
}

/// Manual-graph MLP trainer with switchable numerics.
///
/// The Repro GEMMs route through the size-routed `matmul_in` (packed
/// register-tiled kernel for large products), whose pack buffers come
/// from the thread-local scratch arena — so a multi-step training loop
/// pays the pack/scratch allocations once, not per step.
pub struct Trainer {
    /// Config.
    pub cfg: TrainerConfig,
    /// Numerics under test.
    pub mode: NumericsMode,
    /// Worker pool for the Repro GEMMs (None = process-global pool).
    /// Pool size never changes bits — only wall-clock.
    pool: Option<Arc<WorkerPool>>,
}

impl Trainer {
    /// New trainer on the global pool.
    pub fn new(cfg: TrainerConfig, mode: NumericsMode) -> Self {
        Trainer { cfg, mode, pool: None }
    }

    /// New trainer dispatching its reproducible kernels on an explicit
    /// pool (tests / benchmarks / `--threads`).
    pub fn with_pool(cfg: TrainerConfig, mode: NumericsMode, pool: Arc<WorkerPool>) -> Self {
        Trainer { cfg, mode, pool: Some(pool) }
    }

    fn pool(&self) -> &WorkerPool {
        self.pool.as_deref().unwrap_or_else(|| global_pool())
    }

    fn mm(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        match &self.mode {
            NumericsMode::Repro => matmul_in(self.pool(), a, b),
            NumericsMode::Baseline(p) | NumericsMode::BaselineAtomic(p) => {
                baseline_matmul(a, b, p)
            }
        }
    }

    fn softmax(&self, x: &Tensor) -> Result<Tensor> {
        match &self.mode {
            NumericsMode::Repro => softmax_rows(x),
            NumericsMode::Baseline(p) | NumericsMode::BaselineAtomic(p) => {
                baseline_softmax_rows(x, p)
            }
        }
    }

    /// Column sum for bias gradients: sequential (pooled `sum_axis`,
    /// same row order as the serial loop — bit-identical) in
    /// Repro/Baseline, simulated-atomic order in BaselineAtomic.
    fn col_sum(&self, g: &Tensor) -> Result<Tensor> {
        match &self.mode {
            NumericsMode::BaselineAtomic(_) => {
                let (rows, cols) = (g.dims()[0], g.dims()[1]);
                let mut out = Tensor::zeros(&[cols]);
                for j in 0..cols {
                    let col: Vec<f32> = (0..rows).map(|r| g.data()[r * cols + j]).collect();
                    out.data_mut()[j] = atomic_sum(&col);
                }
                Ok(out)
            }
            _ => sum_axis_in(self.pool(), g, 0),
        }
    }

    /// Run the full training loop.
    pub fn run(&self) -> Result<TrainReport> {
        let c = &self.cfg;
        let n_in = c.side * c.side;
        let ds = GaussianMixtureImages::new(c.side, c.classes, c.batch * c.steps, derive_seed(c.seed, 7));
        // init (identical across modes — isolate numerics, not RNG)
        let mut w1 = crate::rng::kaiming_uniform(&[n_in, c.hidden], derive_seed(c.seed, 0));
        let mut b1 = Tensor::zeros(&[c.hidden]);
        let mut w2 = crate::rng::kaiming_uniform(&[c.hidden, c.classes], derive_seed(c.seed, 1));
        let mut b2 = Tensor::zeros(&[c.classes]);
        let mut curve = Vec::with_capacity(c.steps);
        for step in 0..c.steps {
            let idxs: Vec<usize> = (0..c.batch).map(|i| step * c.batch + i).collect();
            let (x, labels) = ds.batch_flat(&idxs);
            // forward: h = relu(x·w1 + b1); logits = h·w2 + b2
            let h_pre = self.mm(&x, &w1)?.add_t(&b1)?;
            let h = h_pre.map(|v| if v > 0.0 { v } else { 0.0 });
            let logits = self.mm(&h, &w2)?.add_t(&b2)?;
            let probs = self.softmax(&logits)?;
            // loss: mean −log p[target] (library log per mode)
            let mut loss = 0.0f32;
            for (i, &t) in labels.iter().enumerate() {
                let p = probs.data()[i * c.classes + t];
                let lp = match &self.mode {
                    NumericsMode::Repro => crate::rnum::rlog(p),
                    NumericsMode::Baseline(pf) | NumericsMode::BaselineAtomic(pf) => {
                        crate::baseline::log_variant(p, pf.mathlib)
                    }
                };
                loss -= lp;
            }
            loss /= c.batch as f32;
            curve.push(loss);
            // backward (fixed formulas; kernels per mode)
            let mut dlogits = probs.clone();
            for (i, &t) in labels.iter().enumerate() {
                dlogits.data_mut()[i * c.classes + t] -= 1.0;
            }
            let dlogits = dlogits.map(|v| v / c.batch as f32);
            let dw2 = self.mm(&h.transpose2d()?, &dlogits)?;
            let db2 = self.col_sum(&dlogits)?;
            let dh = self.mm(&dlogits, &w2.transpose2d()?)?;
            let dh_pre = dh.zip(&h_pre, |g, v| if v > 0.0 { g } else { 0.0 })?;
            let dw1 = self.mm(&x.transpose2d()?, &dh_pre)?;
            let db1 = self.col_sum(&dh_pre)?;
            // SGD update (fixed graph)
            for (p, g) in [(&mut w1, &dw1), (&mut b1, &db1), (&mut w2, &dw2), (&mut b2, &db2)] {
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data().iter()) {
                    *pv -= c.lr * gv;
                }
            }
        }
        let param_hash = hash_params(&[&w1, &b1, &w2, &b2]);
        Ok(TrainReport { loss_curve: curve, param_hash, params: vec![w1, b1, w2, b2] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repro_mode_is_bit_deterministic() {
        let cfg = TrainerConfig { steps: 20, ..Default::default() };
        let a = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let b = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        assert_eq!(a.param_hash, b.param_hash);
        assert_eq!(
            crate::coordinator::hashing::hash_curve(&a.loss_curve),
            crate::coordinator::hashing::hash_curve(&b.loss_curve)
        );
    }

    #[test]
    fn pool_size_does_not_change_training_bits() {
        // the paper's claim end-to-end: pool size is a pure perf knob
        let cfg = TrainerConfig { steps: 10, ..Default::default() };
        let one = Trainer::with_pool(cfg, NumericsMode::Repro, Arc::new(WorkerPool::new(1)))
            .run()
            .unwrap();
        for lanes in [2usize, 5] {
            let r = Trainer::with_pool(cfg, NumericsMode::Repro, Arc::new(WorkerPool::new(lanes)))
                .run()
                .unwrap();
            assert_eq!(one.param_hash, r.param_hash, "lanes={lanes}");
        }
    }

    #[test]
    fn training_learns() {
        let cfg = TrainerConfig { steps: 60, ..Default::default() };
        let r = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let first: f32 = r.loss_curve[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = r.loss_curve[r.loss_curve.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(last < first * 0.8, "loss {first} -> {last}");
    }

    #[test]
    fn atomic_mode_diverges_run_to_run() {
        let cfg = TrainerConfig { steps: 15, ..Default::default() };
        let p = PlatformProfile::reference();
        let a = Trainer::new(cfg, NumericsMode::BaselineAtomic(p)).run().unwrap();
        let b = Trainer::new(cfg, NumericsMode::BaselineAtomic(p)).run().unwrap();
        assert_ne!(a.param_hash, b.param_hash, "atomics were deterministic?!");
    }

    #[test]
    fn platforms_diverge_under_baseline_but_not_repro() {
        let cfg = TrainerConfig { steps: 15, ..Default::default() };
        let zoo = PlatformProfile::zoo();
        let base: Vec<String> = zoo
            .iter()
            .map(|p| Trainer::new(cfg, NumericsMode::Baseline(*p)).run().unwrap().param_hash)
            .collect();
        assert!(
            base.iter().any(|h| h != &base[0]),
            "baseline identical across all simulated platforms"
        );
        // repro mode doesn't depend on the profile at all (same code path)
        let r1 = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let r2 = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        assert_eq!(r1.param_hash, r2.param_hash);
    }
}
