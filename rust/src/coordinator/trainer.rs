//! Training loops with switchable numerics — the E1/E2/E8 engine.
//!
//! [`NumericsMode::Repro`] runs RepDL kernels; the other modes run the
//! conventional [`crate::baseline`] kernels under a simulated platform or
//! with simulated atomics — the experiment's control group. The MLP
//! trainer implements its forward/backward *manually* so the identical
//! mathematical graph runs under either numerics (only the kernels —
//! reduction order, libm, FMA — change, matching the paper's taxonomy).
//!
//! Since PR 8 the trainer is **step-driven**: all mutable run state
//! lives in a [`TrainState`] (parameters, optimizer slots, step counter,
//! RNG stream position) and [`Trainer::step`] advances it by exactly one
//! optimizer step. `Trainer::run` is nothing but `init_state` + a step
//! loop, so a checkpointed resume executes the *same* code path as an
//! uninterrupted run — the resume≡uninterrupted bit-equality argument
//! (DESIGN.md §12) reduces to `TrainState` round-tripping exactly.
//!
//! Gradient computation is factored into [`Trainer::grad_microbatch`], a
//! pure function of (params, microbatch, mask) returning **sample-summed**
//! gradients. One full batch = one microbatch here; the data-parallel
//! engine ([`crate::coordinator::train::DataParallelTrainer`]) calls the
//! same function once per microbatch and combines the partial sums in a
//! fixed tree order.

use crate::baseline::{atomic_sum, baseline_matmul, baseline_softmax_rows, PlatformProfile};
use crate::coordinator::hashing::hash_params;
use crate::coordinator::train::{TrainOptimizer, TrainState};
use crate::data::GaussianMixtureImages;
use crate::nn::softmax_rows;
use crate::rng::{derive_seed, Philox, ReproRng};
use crate::tensor::{global_pool, matmul_in, sum_axis_in, Tensor, WorkerPool};
use crate::{Error, Result};
use std::sync::Arc;

/// Philox stream id for the per-epoch data permutation (the generator is
/// keyed by `derive_seed(seed, epoch)`; the stream id only has to be
/// fixed).
const PERM_STREAM: u64 = 0xDA7A;

/// `derive_seed` worker index for the trainer's noise stream (dropout
/// masks). Indices 0/1 key the weight initialisers and 7 keys the
/// dataset, so the noise stream is disjoint from both.
const NOISE_WORKER: u64 = 2;

/// Which numerics the trainer runs.
#[derive(Clone, Copy, Debug)]
pub enum NumericsMode {
    /// RepDL reproducible kernels.
    Repro,
    /// Conventional kernels under a simulated platform.
    Baseline(PlatformProfile),
    /// Conventional kernels + simulated atomic-order bias-gradient
    /// reduction (run-to-run non-deterministic).
    BaselineAtomic(PlatformProfile),
}

/// Trainer configuration (2-layer MLP on the synthetic image task).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainerConfig {
    /// Input side (images are side×side).
    pub side: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Classes.
    pub classes: usize,
    /// Batch size.
    pub batch: usize,
    /// Steps.
    pub steps: usize,
    /// Learning rate.
    pub lr: f32,
    /// Base seed (init + data order + noise).
    pub seed: u64,
    /// Dropout probability on the hidden layer (0 disables; inverted
    /// dropout, masks drawn from the [`TrainState`] noise stream so a
    /// resumed run continues the stream mid-position).
    pub dropout: f32,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            side: 8,
            hidden: 32,
            classes: 4,
            batch: 16,
            steps: 60,
            lr: 0.2,
            seed: 42,
            dropout: 0.0,
        }
    }
}

/// Optimizer selection for the step engine. `lr` comes from
/// [`TrainerConfig::lr`]; this enum carries only the per-family
/// hyperparameters, and is plain data so checkpoints can serialize it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimizerCfg {
    /// SGD (momentum 0 reproduces the historical inline `p -= lr·g`).
    Sgd {
        /// Momentum coefficient (0 disables the slot buffers).
        momentum: f32,
        /// L2 weight decay.
        weight_decay: f32,
    },
    /// Adam with PyTorch defaults (β₁ 0.9, β₂ 0.999, ε 1e-8).
    Adam,
}

impl Default for OptimizerCfg {
    fn default() -> Self {
        OptimizerCfg::Sgd { momentum: 0.0, weight_decay: 0.0 }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Loss at every step.
    pub loss_curve: Vec<f32>,
    /// SHA-256 of the final parameters.
    pub param_hash: String,
    /// Final parameters (w1, b1, w2, b2).
    pub params: Vec<Tensor>,
}

/// Sample-summed gradients for one microbatch, plus the (sequentially
/// accumulated) loss sum over its samples. Partial sums compose: the
/// full-batch gradient is the elementwise sum of the microbatch sums
/// divided once by the batch size (see `finalize_grads`).
pub(crate) struct MicroGrad {
    /// Gradient sums, aligned with the parameter order (w1, b1, w2, b2).
    pub grads: Vec<Tensor>,
    /// Σ over samples of −log p[target].
    pub loss_sum: f32,
}

/// The batch's dataset indices for a logical step: a slice of the
/// per-epoch Philox-keyed permutation (epoch = step / steps-per-epoch,
/// generator keyed by `derive_seed(seed, epoch)`). A pure function of
/// (config, step) — a resumed run recomputes the identical data order,
/// and the permutation visits every sample exactly once per epoch.
pub fn batch_indices(cfg: &TrainerConfig, step: u64) -> Vec<usize> {
    let len = cfg.batch * cfg.steps;
    let steps_per_epoch = cfg.steps.max(1) as u64;
    let epoch = step / steps_per_epoch;
    let within = (step % steps_per_epoch) as usize;
    let mut perm: Vec<usize> = (0..len).collect();
    Philox::new(derive_seed(cfg.seed, epoch), PERM_STREAM).shuffle(&mut perm);
    perm[within * cfg.batch..(within + 1) * cfg.batch].to_vec()
}

/// Draw the step's inverted-dropout mask (batch × hidden) from the
/// state's noise stream: values are `1/keep` with probability `keep`,
/// else 0. Drawn row-major on the coordinator thread — the draw order
/// never depends on lane count, and the stream position advances by
/// exactly `batch·hidden` bernoullis per step, so a snapshot/restore of
/// the generator resumes the mask sequence mid-stream.
pub(crate) fn draw_mask(cfg: &TrainerConfig, noise: &mut Philox) -> Result<Option<Tensor>> {
    if cfg.dropout <= 0.0 {
        return Ok(None);
    }
    if cfg.dropout >= 1.0 {
        return Err(Error::config(format!("dropout {} must be < 1", cfg.dropout)));
    }
    let keep = 1.0 - cfg.dropout;
    let scale = 1.0 / keep;
    let n = cfg.batch * cfg.hidden;
    let data: Vec<f32> = (0..n).map(|_| noise.bernoulli(keep) * scale).collect();
    Ok(Some(Tensor::from_vec(&[cfg.batch, cfg.hidden], data)?))
}

/// Divide the summed gradients (and loss sum) by the full batch size —
/// exactly one division per element, placed *after* all cross-microbatch
/// combination, so the division graph is identical for every microbatch
/// decomposition.
pub(crate) fn finalize_grads(mg: MicroGrad, batch: usize) -> (Vec<Tensor>, f32) {
    let b = batch as f32;
    let grads = mg.grads.into_iter().map(|g| g.map(|v| v / b)).collect();
    (grads, mg.loss_sum / b)
}

/// Manual-graph MLP trainer with switchable numerics.
///
/// The Repro GEMMs route through the size-routed `matmul_in` (packed
/// register-tiled kernel for large products), whose pack buffers come
/// from the thread-local scratch arena — so a multi-step training loop
/// pays the pack/scratch allocations once, not per step.
pub struct Trainer {
    /// Config.
    pub cfg: TrainerConfig,
    /// Numerics under test.
    pub mode: NumericsMode,
    /// Optimizer family + hyperparameters.
    pub opt: OptimizerCfg,
    /// Worker pool for the Repro GEMMs (None = process-global pool).
    /// Pool size never changes bits — only wall-clock.
    pool: Option<Arc<WorkerPool>>,
}

impl Trainer {
    /// New trainer on the global pool (default SGD).
    pub fn new(cfg: TrainerConfig, mode: NumericsMode) -> Self {
        Trainer { cfg, mode, opt: OptimizerCfg::default(), pool: None }
    }

    /// New trainer dispatching its reproducible kernels on an explicit
    /// pool (tests / benchmarks / `--threads`).
    pub fn with_pool(cfg: TrainerConfig, mode: NumericsMode, pool: Arc<WorkerPool>) -> Self {
        Trainer { cfg, mode, opt: OptimizerCfg::default(), pool: Some(pool) }
    }

    /// Select the optimizer family (builder style).
    pub fn optimizer(mut self, opt: OptimizerCfg) -> Self {
        self.opt = opt;
        self
    }

    fn pool(&self) -> &WorkerPool {
        self.pool.as_deref().unwrap_or_else(|| global_pool())
    }

    /// The training dataset — a pure function of the config, so it is
    /// rebuilt (never serialized) on resume.
    pub(crate) fn dataset(&self) -> GaussianMixtureImages {
        let c = &self.cfg;
        GaussianMixtureImages::new(c.side, c.classes, c.batch * c.steps, derive_seed(c.seed, 7))
    }

    /// Fresh run state: initial parameters (identical across modes —
    /// isolate numerics, not RNG), zeroed optimizer slots, and the noise
    /// stream at position 0.
    pub fn init_state(&self) -> TrainState {
        let c = &self.cfg;
        let n_in = c.side * c.side;
        let w1 = crate::rng::kaiming_uniform(&[n_in, c.hidden], derive_seed(c.seed, 0));
        let b1 = Tensor::zeros(&[c.hidden]);
        let w2 = crate::rng::kaiming_uniform(&[c.hidden, c.classes], derive_seed(c.seed, 1));
        let b2 = Tensor::zeros(&[c.classes]);
        TrainState {
            step: 0,
            params: vec![w1, b1, w2, b2],
            opt: TrainOptimizer::from_cfg(self.opt, c.lr),
            noise: Philox::new(derive_seed(c.seed, NOISE_WORKER), 0),
        }
    }

    fn mm(&self, pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        match &self.mode {
            NumericsMode::Repro => matmul_in(pool, a, b),
            NumericsMode::Baseline(p) | NumericsMode::BaselineAtomic(p) => {
                baseline_matmul(a, b, p)
            }
        }
    }

    fn softmax(&self, x: &Tensor) -> Result<Tensor> {
        match &self.mode {
            NumericsMode::Repro => softmax_rows(x),
            NumericsMode::Baseline(p) | NumericsMode::BaselineAtomic(p) => {
                baseline_softmax_rows(x, p)
            }
        }
    }

    /// Column sum for bias gradients: sequential (pooled `sum_axis`,
    /// same row order as the serial loop — bit-identical) in
    /// Repro/Baseline, simulated-atomic order in BaselineAtomic.
    fn col_sum(&self, pool: &WorkerPool, g: &Tensor) -> Result<Tensor> {
        match &self.mode {
            NumericsMode::BaselineAtomic(_) => {
                let (rows, cols) = (g.dims()[0], g.dims()[1]);
                let mut out = Tensor::zeros(&[cols]);
                for j in 0..cols {
                    let col: Vec<f32> = (0..rows).map(|r| g.data()[r * cols + j]).collect();
                    out.data_mut()[j] = atomic_sum(&col);
                }
                Ok(out)
            }
            _ => sum_axis_in(pool, g, 0),
        }
    }

    /// Forward + backward over one microbatch: a pure function of
    /// (params, x, labels, mask rows) returning **sample-summed**
    /// gradients (no 1/batch scaling — see `finalize_grads`). The GEMMs
    /// dispatch on `pool`; callers running *inside* a pool task must
    /// pass a 1-lane pool (inline execution — see `tensor/pool.rs` on
    /// nested dispatch). Pool size never changes the bits.
    ///
    /// Graph: `h = relu(x·w1 + b1) ⊙ mask; logits = h·w2 + b2;`
    /// `loss_i = −log softmax(logits)_i[target_i]`, backward by the
    /// matching fixed formulas (kernels per [`NumericsMode`]).
    pub(crate) fn grad_microbatch(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        labels: &[usize],
        mask: Option<&Tensor>,
        params: &[Tensor],
    ) -> Result<MicroGrad> {
        if params.len() != 4 {
            return Err(Error::shape(format!("trainer expects 4 params, got {}", params.len())));
        }
        let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
        let classes = self.cfg.classes;
        // forward
        let h_pre = self.mm(pool, x, w1)?.add_t(b1)?;
        let hr = h_pre.map(|v| if v > 0.0 { v } else { 0.0 });
        let h = match mask {
            Some(m) => hr.zip(m, |a, b| a * b)?,
            None => hr,
        };
        let logits = self.mm(pool, &h, w2)?.add_t(b2)?;
        let probs = self.softmax(&logits)?;
        // loss sum: Σ −log p[target] in sample order (library log per mode)
        let mut loss_sum = 0.0f32;
        for (i, &t) in labels.iter().enumerate() {
            let p = probs.data()[i * classes + t];
            let lp = match &self.mode {
                NumericsMode::Repro => crate::rnum::rlog(p),
                NumericsMode::Baseline(pf) | NumericsMode::BaselineAtomic(pf) => {
                    crate::baseline::log_variant(p, pf.mathlib)
                }
            };
            loss_sum -= lp;
        }
        // backward (fixed formulas; kernels per mode); dlogits is the
        // *unscaled* softmax-CE gradient — sums compose across microbatches
        let mut dlogits = probs.clone();
        for (i, &t) in labels.iter().enumerate() {
            dlogits.data_mut()[i * classes + t] -= 1.0;
        }
        let dw2 = self.mm(pool, &h.transpose2d()?, &dlogits)?;
        let db2 = self.col_sum(pool, &dlogits)?;
        let dh = self.mm(pool, &dlogits, &w2.transpose2d()?)?;
        let dh = match mask {
            Some(m) => dh.zip(m, |g, b| g * b)?,
            None => dh,
        };
        let dh_pre = dh.zip(&h_pre, |g, v| if v > 0.0 { g } else { 0.0 })?;
        let dw1 = self.mm(pool, &x.transpose2d()?, &dh_pre)?;
        let db1 = self.col_sum(pool, &dh_pre)?;
        Ok(MicroGrad { grads: vec![dw1, db1, dw2, db2], loss_sum })
    }

    /// Advance the state by exactly one optimizer step (one full batch,
    /// computed as a single microbatch) and return the step's mean loss.
    /// A pure state transition: `step(load(save(s))) ≡ step(s)`
    /// bit-for-bit, which is the whole checkpoint/resume contract.
    pub fn step(&self, st: &mut TrainState) -> Result<f32> {
        let c = &self.cfg;
        let ds = self.dataset();
        let idxs = batch_indices(c, st.step);
        let (x, labels) = ds.batch_flat(&idxs);
        let mask = draw_mask(c, &mut st.noise)?;
        let mg = self.grad_microbatch(self.pool(), &x, &labels, mask.as_ref(), &st.params)?;
        let (grads, loss) = finalize_grads(mg, c.batch);
        st.opt.step(&mut st.params, &grads)?;
        st.step += 1;
        Ok(loss)
    }

    /// Run `cfg.steps` steps from a fresh state.
    pub fn run(&self) -> Result<TrainReport> {
        let mut st = self.init_state();
        let mut curve = Vec::with_capacity(self.cfg.steps);
        for _ in 0..self.cfg.steps {
            curve.push(self.step(&mut st)?);
        }
        Ok(report(st, curve))
    }
}

/// Package a finished state + loss curve into a [`TrainReport`].
pub(crate) fn report(st: TrainState, curve: Vec<f32>) -> TrainReport {
    let refs: Vec<&Tensor> = st.params.iter().collect();
    let param_hash = hash_params(&refs);
    TrainReport { loss_curve: curve, param_hash, params: st.params }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repro_mode_is_bit_deterministic() {
        let cfg = TrainerConfig { steps: 20, ..Default::default() };
        let a = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let b = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        assert_eq!(a.param_hash, b.param_hash);
        assert_eq!(
            crate::coordinator::hashing::hash_curve(&a.loss_curve),
            crate::coordinator::hashing::hash_curve(&b.loss_curve)
        );
    }

    #[test]
    fn pool_size_does_not_change_training_bits() {
        // the paper's claim end-to-end: pool size is a pure perf knob
        let cfg = TrainerConfig { steps: 10, ..Default::default() };
        let one = Trainer::with_pool(cfg, NumericsMode::Repro, Arc::new(WorkerPool::new(1)))
            .run()
            .unwrap();
        for lanes in [2usize, 5] {
            let r = Trainer::with_pool(cfg, NumericsMode::Repro, Arc::new(WorkerPool::new(lanes)))
                .run()
                .unwrap();
            assert_eq!(one.param_hash, r.param_hash, "lanes={lanes}");
        }
    }

    #[test]
    fn training_learns() {
        let cfg = TrainerConfig { steps: 60, ..Default::default() };
        let r = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let first: f32 = r.loss_curve[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = r.loss_curve[r.loss_curve.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(last < first * 0.8, "loss {first} -> {last}");
    }

    #[test]
    fn atomic_mode_diverges_run_to_run() {
        let cfg = TrainerConfig { steps: 15, ..Default::default() };
        let p = PlatformProfile::reference();
        let a = Trainer::new(cfg, NumericsMode::BaselineAtomic(p)).run().unwrap();
        let b = Trainer::new(cfg, NumericsMode::BaselineAtomic(p)).run().unwrap();
        assert_ne!(a.param_hash, b.param_hash, "atomics were deterministic?!");
    }

    #[test]
    fn platforms_diverge_under_baseline_but_not_repro() {
        let cfg = TrainerConfig { steps: 15, ..Default::default() };
        let zoo = PlatformProfile::zoo();
        let base: Vec<String> = zoo
            .iter()
            .map(|p| Trainer::new(cfg, NumericsMode::Baseline(*p)).run().unwrap().param_hash)
            .collect();
        assert!(
            base.iter().any(|h| h != &base[0]),
            "baseline identical across all simulated platforms"
        );
        // repro mode doesn't depend on the profile at all (same code path)
        let r1 = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let r2 = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        assert_eq!(r1.param_hash, r2.param_hash);
    }

    #[test]
    fn epoch_shuffle_is_a_deterministic_permutation() {
        let cfg = TrainerConfig::default();
        let len = cfg.batch * cfg.steps;
        // every epoch-0 batch together covers the dataset exactly once
        let mut seen: Vec<usize> = (0..cfg.steps as u64)
            .flat_map(|s| batch_indices(&cfg, s))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..len).collect::<Vec<_>>());
        // shuffled (not the sequential order), but reproducible
        assert_ne!(batch_indices(&cfg, 0), (0..cfg.batch).collect::<Vec<_>>());
        assert_eq!(batch_indices(&cfg, 3), batch_indices(&cfg, 3));
        // a different epoch reshuffles (step steps_per_epoch wraps around)
        assert_ne!(batch_indices(&cfg, 0), batch_indices(&cfg, cfg.steps as u64));
        // a different seed reshuffles
        let cfg2 = TrainerConfig { seed: 43, ..cfg };
        assert_ne!(batch_indices(&cfg, 0), batch_indices(&cfg2, 0));
    }

    #[test]
    fn step_loop_matches_run_and_dropout_is_deterministic() {
        let cfg = TrainerConfig { steps: 12, dropout: 0.25, ..Default::default() };
        let tr = Trainer::new(cfg, NumericsMode::Repro);
        let r = tr.run().unwrap();
        let mut st = tr.init_state();
        let curve: Vec<f32> = (0..cfg.steps).map(|_| tr.step(&mut st).unwrap()).collect();
        assert_eq!(
            crate::coordinator::hashing::hash_curve(&r.loss_curve),
            crate::coordinator::hashing::hash_curve(&curve)
        );
        assert_eq!(r.param_hash, st.param_hash());
        // dropout draws come from the state's stream: two fresh runs agree
        let r2 = tr.run().unwrap();
        assert_eq!(r.param_hash, r2.param_hash);
        // and training still learns through the mask (weaker bound)
        assert!(r.loss_curve.last().unwrap() < r.loss_curve.first().unwrap());
    }

    #[test]
    fn adam_trainer_is_deterministic_and_learns() {
        let cfg = TrainerConfig { steps: 30, lr: 0.01, ..Default::default() };
        let mk = || Trainer::new(cfg, NumericsMode::Repro).optimizer(OptimizerCfg::Adam);
        let a = mk().run().unwrap();
        let b = mk().run().unwrap();
        assert_eq!(a.param_hash, b.param_hash);
        let first: f32 = a.loss_curve[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = a.loss_curve[a.loss_curve.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(last < first, "adam loss {first} -> {last}");
    }
}
