//! `repdl` — the RepDL leader binary.
//!
//! Subcommands:
//!   train        train the MLP workload (choose numerics: repro/baseline/atomic)
//!                (--lanes L --microbatch M data-parallel fixed-order
//!                 gradient reduction; --optimizer sgd|adam [--momentum
//!                 --weight-decay] --dropout P; --checkpoint DIR
//!                 [--checkpoint-every K] writes bit-exact REPDLCKP
//!                 checkpoints, --resume continues from the newest intact
//!                 one, --promote installs the final checkpoint into a
//!                 ModelRegistry and verifies the served bits)
//!   verify       E1/E2 style run-twice + cross-platform verification
//!   transformer  train the char transformer (E8 workload)
//!   serve        E7 batch-invariance report + pooled throughput + the
//!                deterministic dynamic-batching scheduler
//!                (--model linear|mlp|transformer --threads N --shards S
//!                 --batch-window K --clients C --max-queue-depth D
//!                 --cache-capacity M --replay; transformer towers take
//!                 --width/--heads/--layers/--context plus --sessions
//!                 [--session-capacity S] for KV-cached incremental
//!                 decode over a growing-prefix stream queue, mlp takes
//!                 --hidden; --tp N serves mlp/transformer through N
//!                 tensor-parallel shards — a pure layout knob whose
//!                 bits, hashes and journals are invariant across
//!                 N ∈ {1,2,4}; --journal PATH appends the durable event
//!                 journal, --recover rebuilds from an existing one
//!                 before serving, --journal-degrade picks
//!                 degrade-to-memory over fail-stop; --flush-every K
//!                 publishes a batch cut every K admitted tickets — the
//!                 logical-clock latency control, wall-clock timers stay
//!                 banned; --listen HOST:PORT serves the model over the
//!                 length-prefixed TCP wire protocol instead of running
//!                 the in-process client loop — DESIGN.md §14)
//!   request      remote client for a `serve --listen` server
//!                (--connect HOST:PORT --model M --requests N; generates
//!                 the same deterministic request queue as `serve` and
//!                 prints each response's ticket and bit hash)
//!   runtime      load + execute an AOT artifact (needs `make artifacts`)
//!   selftest     quick determinism smoke checks

use repdl::baseline::PlatformProfile;
use repdl::cli::Args;
use repdl::coordinator::{compare_runs, DeterministicServer, NumericsMode, Trainer, TrainerConfig};
use repdl::data::SyntheticCorpus;
use repdl::nn::{CharTransformer, TransformerConfig};
use repdl::optim::Adam;
use repdl::tensor::Tensor;

fn main() -> std::process::ExitCode {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("train") => cmd_train(&args),
        Some("verify") => cmd_verify(&args),
        Some("transformer") => cmd_transformer(&args),
        Some("serve") => cmd_serve(&args),
        Some("request") => cmd_request(&args),
        Some("runtime") => cmd_runtime(&args),
        Some("selftest") => cmd_selftest(),
        _ => {
            eprintln!(
                "usage: repdl <train|verify|transformer|serve|request|runtime|selftest> [--flags]\n\
                 try: repdl verify --steps 40"
            );
            2
        }
    };
    // orderly shutdown: returning (instead of `std::process::exit`) runs
    // every destructor on the way out — schedulers drain and join their
    // dispatchers, and the serve journal drains its buffered response
    // records and fsyncs, so a clean exit always leaves a clean journal
    std::process::ExitCode::from(code as u8)
}

/// Strict `--tp N` parse: absent → `None` (the unsharded towers).
/// Present, it must be an integer ≥ 1 — the lenient `Args` helpers
/// would silently substitute a default for garbage here, and a silently
/// changed tensor-parallel width is exactly the kind of drift this flag
/// exists to rule out. Whether N actually divides the shard plan is the
/// tower constructor's job (a construction error, not a usage error).
fn parse_tp(args: &Args) -> std::result::Result<Option<usize>, String> {
    if !args.has("tp") {
        return Ok(None);
    }
    let raw = args.get_str("tp", "");
    match raw.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(Some(n)),
        _ => Err(format!("--tp {raw}: want an integer >= 1")),
    }
}

fn trainer_cfg(args: &Args) -> TrainerConfig {
    TrainerConfig {
        side: args.get_usize("side", 8),
        hidden: args.get_usize("hidden", 32),
        classes: args.get_usize("classes", 4),
        batch: args.get_usize("batch", 16),
        steps: args.get_usize("steps", 60),
        lr: args.get_f32("lr", 0.2),
        seed: args.get_u64("seed", 42),
        dropout: args.get_f32("dropout", 0.0),
    }
}

fn cmd_train(args: &Args) -> i32 {
    use repdl::coordinator::{
        checkpoint_path, latest_checkpoint, save_checkpoint, Checkpoint, CheckpointMeta,
        DataParallelTrainer, ModelRegistry, OptimizerCfg, ServeConfig,
    };
    use repdl::tensor::global_pool_handle;
    if args.has("tp") {
        // promotion is TP-agnostic by design: a checkpoint promotes to
        // the registry's unsharded tower, and a serve deployment picks
        // its own width later (`repdl serve --tp N`). The weights hash
        // and journal keys are identical at every width, so baking a
        // width into the training artifact would add a knob that cannot
        // change bits but could desync deployments.
        eprintln!(
            "train: --tp is a serve-time flag (promotion is TP-agnostic); \
             use `repdl serve --tp N`"
        );
        return 2;
    }
    let cfg = trainer_cfg(args);
    let mode_str = args.get_str("mode", "repro");
    let ckpt_dir = args.get_opt_str("checkpoint").map(std::path::PathBuf::from);
    let do_resume = args.has("resume");
    let do_promote = args.has("promote");
    // baseline numerics keep the historical monolithic loop — the
    // step/checkpoint engine is the reproducible path only (a baseline
    // checkpoint could not honour resume≡uninterrupted anyway)
    if mode_str != "repro" {
        if ckpt_dir.is_some() || do_resume || do_promote {
            eprintln!("--checkpoint/--resume/--promote need --mode repro");
            return 2;
        }
        let mode = match mode_str.as_str() {
            "baseline" => NumericsMode::Baseline(PlatformProfile::reference()),
            "atomic" => NumericsMode::BaselineAtomic(PlatformProfile::reference()),
            other => {
                eprintln!("unknown --mode {other}");
                return 2;
            }
        };
        return match Trainer::new(cfg, mode).run() {
            Ok(r) => {
                for (i, l) in r.loss_curve.iter().enumerate() {
                    if i % 10 == 0 || i + 1 == r.loss_curve.len() {
                        println!("step {i:>4}  loss {l:.6}");
                    }
                }
                println!("param_hash {}", r.param_hash);
                0
            }
            Err(e) => {
                eprintln!("train failed: {e}");
                1
            }
        };
    }
    let opt = match args.get_str("optimizer", "sgd").as_str() {
        "sgd" => OptimizerCfg::Sgd {
            momentum: args.get_f32("momentum", 0.0),
            weight_decay: args.get_f32("weight-decay", 0.0),
        },
        "adam" => OptimizerCfg::Adam,
        other => {
            eprintln!("unknown --optimizer {other} (want sgd|adam)");
            return 2;
        }
    };
    let lanes = args.get_usize_at_least("lanes", 1, 1);
    let microbatch = args.get_usize_at_least("microbatch", cfg.batch.min(4), 1);
    let every = args.get_usize_at_least("checkpoint-every", 10, 1) as u64;
    let engine = match DataParallelTrainer::new(cfg, lanes, microbatch) {
        Ok(e) => e.optimizer(opt),
        Err(e) => {
            eprintln!("train: {e}");
            return 2;
        }
    };
    let meta = CheckpointMeta { cfg, opt, microbatch };
    // resume from the newest intact checkpoint, or start fresh
    let (mut st, mut curve) = match (&ckpt_dir, do_resume) {
        (Some(dir), true) if dir.is_dir() => match latest_checkpoint(dir) {
            Ok(scan) => {
                for (path, why) in &scan.rejected {
                    eprintln!("checkpoint skipped {}: {why}", path.display());
                }
                match scan.loaded {
                    Some((path, ckpt)) => {
                        if let Err(e) = ckpt.meta.ensure_matches(&meta) {
                            eprintln!("resume refused: {e}");
                            return 2;
                        }
                        println!("resumed from step {} ({})", ckpt.step, path.display());
                        match ckpt.into_state() {
                            Ok(sc) => sc,
                            Err(e) => {
                                eprintln!("resume failed: {e}");
                                return 1;
                            }
                        }
                    }
                    None => (engine.init_state(), Vec::new()),
                }
            }
            Err(e) => {
                eprintln!("checkpoint scan failed: {e}");
                return 1;
            }
        },
        _ => (engine.init_state(), Vec::new()),
    };
    if let Some(dir) = &ckpt_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("checkpoint dir: {e}");
            return 1;
        }
    }
    while (st.step as usize) < cfg.steps {
        let loss = match engine.step(&mut st) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("train failed: {e}");
                return 1;
            }
        };
        curve.push(loss);
        let i = st.step - 1;
        if i % 10 == 0 || st.step as usize == cfg.steps {
            println!("step {i:>4}  loss {loss:.6}");
        }
        if let Some(dir) = &ckpt_dir {
            if st.step % every == 0 || st.step as usize == cfg.steps {
                let path = checkpoint_path(dir, st.step);
                if let Err(e) = save_checkpoint(&path, &meta, &st, &curve) {
                    eprintln!("checkpoint save failed: {e}");
                    return 1;
                }
            }
        }
    }
    println!("param_hash {}", st.param_hash());
    if !do_promote {
        return 0;
    }
    // train→serve promotion: install the final state as a live model and
    // verify the served bits against direct inference on the weights
    let ckpt = Checkpoint::capture(meta, &st, &curve);
    let pool = global_pool_handle();
    let mut reg = ModelRegistry::new();
    let promo = match reg.promote("mlp", &ckpt, 1, pool.clone(), ServeConfig::default()) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("promote failed: {e}");
            return 1;
        }
    };
    println!(
        "promoted model_id={} watermark={} weights_hash={}",
        promo.model_id,
        promo.watermark,
        &promo.weights_hash[..16]
    );
    let mlp = match ckpt.to_mlp() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("promote failed: {e}");
            return 1;
        }
    };
    let d_in = cfg.side * cfg.side;
    let reqs: Vec<Tensor> = (0..8)
        .map(|i| repdl::rng::uniform_tensor(&[d_in], -1.0, 1.0, 900 + i as u64))
        .collect();
    let mut x = Tensor::zeros(&[reqs.len(), d_in]);
    for (i, r) in reqs.iter().enumerate() {
        x.data_mut()[i * d_in..(i + 1) * d_in].copy_from_slice(r.data());
    }
    let direct = match mlp.forward_infer_in(&pool, &x) {
        Ok(y) => y,
        Err(e) => {
            eprintln!("promote verify failed: {e}");
            return 1;
        }
    };
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| reg.submit("mlp", r.clone()).expect("submit"))
        .collect();
    reg.flush_all();
    let mut mismatches = 0usize;
    for (i, p) in pending.into_iter().enumerate() {
        let out = p.wait().expect("serve");
        if out.data() != &direct.data()[i * cfg.classes..(i + 1) * cfg.classes] {
            mismatches += 1;
        }
    }
    println!("promotion served={} mismatches={mismatches}", reqs.len());
    if mismatches == 0 {
        0
    } else {
        1
    }
}

fn cmd_verify(args: &Args) -> i32 {
    let cfg = trainer_cfg(args);
    println!("== run-to-run (RepDL) ==");
    let a = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    let b = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    let c = compare_runs(&a.loss_curve, &b.loss_curve, &a.param_hash, &b.param_hash);
    println!("identical={} first_div={:?}", c.curves_identical, c.first_divergence);
    println!("\n== cross-platform (simulated zoo, baseline numerics) ==");
    println!("{:<22} {:>18}", "platform", "first-div-step");
    let reference = Trainer::new(cfg, NumericsMode::Baseline(PlatformProfile::reference()))
        .run()
        .unwrap();
    for p in PlatformProfile::zoo() {
        let r = Trainer::new(cfg, NumericsMode::Baseline(p)).run().unwrap();
        let cmp = compare_runs(
            &reference.loss_curve,
            &r.loss_curve,
            &reference.param_hash,
            &r.param_hash,
        );
        println!(
            "{:<22} {:>18}",
            p.name,
            cmp.first_divergence.map(|s| s.to_string()).unwrap_or_else(|| "-".into())
        );
    }
    if c.curves_identical {
        0
    } else {
        1
    }
}

fn cmd_transformer(args: &Args) -> i32 {
    let steps = args.get_usize("steps", 100);
    let seed = args.get_u64("seed", 7);
    let cfg = TransformerConfig {
        vocab: 28,
        dim: args.get_usize("dim", 32),
        heads: args.get_usize("heads", 4),
        layers: args.get_usize("layers", 2),
        context: args.get_usize("context", 16),
        mlp_ratio: 2,
    };
    let corpus = SyntheticCorpus::generate(20_000, seed);
    let mut model = CharTransformer::new(cfg, seed).expect("model");
    let mut opt = Adam::new(args.get_f32("lr", 1e-2));
    println!("params: {}", model.num_params());
    for step in 0..steps {
        let pos = (step * 97) % corpus.num_windows(cfg.context);
        let ids: Vec<usize> = corpus.window(pos, cfg.context).to_vec();
        let mut tape = repdl::autograd::Tape::new();
        let mut binds = Vec::new();
        let loss = model.loss_on_sequence(&mut tape, &ids, &mut binds).expect("fwd");
        tape.backward(loss).expect("bwd");
        let grads: Vec<Tensor> = binds.iter().map(|v| tape.grad(*v).unwrap()).collect();
        opt.step(model.params_mut(), &grads).expect("opt");
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {:.6}", tape.value(loss).data()[0]);
        }
    }
    let params = model.params_mut();
    let refs: Vec<&Tensor> = params.iter().map(|p| &**p).collect();
    println!("param_hash {}", repdl::coordinator::hash_params(&refs));
    0
}

fn cmd_serve(args: &Args) -> i32 {
    use repdl::coordinator::{
        read_journal, Journal, JournalPolicy, MlpTower, ModelTower, ServeConfig,
        ServeScheduler, ShardedTower, TransformerTower,
    };
    use repdl::nn::{Act, Mlp};
    use repdl::tensor::{global_pool_handle, WorkerPool};
    use std::sync::Arc;
    let model = args.get_str("model", "linear");
    let d = args.get_usize("dim", 256);
    let n = args.get_usize("requests", 64);
    let shards = args.get_usize_at_least("shards", 1, 1);
    let window = args.get_usize_at_least("batch-window", 16, 1);
    let clients = args.get_usize_at_least("clients", 2, 1);
    // admission + audit policy (ISSUE 4): 0 / absent = unbounded / off;
    // --replay implies the ticket-addressed response log
    let max_queue_depth = args.get_opt_usize("max-queue-depth");
    let cache_capacity = args.get_usize("cache-capacity", 0);
    let do_replay = args.has("replay");
    // logical-clock flush (ISSUE 10): a cut every K admitted tickets —
    // the deterministic replacement for a wall-clock batching timer
    let flush_every = args.get_opt_usize("flush-every").map(|k| k as u64);
    if flush_every == Some(0) {
        eprintln!("serve: --flush-every 0 makes no sense (want K >= 1)");
        return 2;
    }
    // TCP front end (ISSUE 10): present, the scheduler goes behind a
    // ModelRegistry + NetServer instead of the in-process client loop
    let listen = args.get_opt_str("listen");
    // durable event journal (ISSUE 7): --journal PATH appends the
    // crash-consistent event journal; --recover rebuilds serving state
    // from an existing one before accepting new requests (the
    // cross-process reproducibility story); recovery implies the
    // response log, which it rebuilds
    let journal_path = args.get_opt_str("journal").map(std::path::PathBuf::from);
    let do_recover = args.has("recover");
    let journal_policy = if args.has("journal-degrade") {
        JournalPolicy::DegradeToMemory
    } else {
        JournalPolicy::FailStop
    };
    // KV sessions (transformer only): --sessions turns the store on,
    // --session-capacity bounds it (deterministic ticket-FIFO eviction)
    let session_capacity = if args.has("sessions") {
        args.get_usize_at_least("session-capacity", 256, 1)
    } else {
        0
    };
    // only spawn a private pool for an explicit --threads; otherwise
    // take a handle to the global pool the kernels already use (never
    // a duplicate pool of background threads)
    let pool = args
        .threads()
        .map(WorkerPool::shared)
        .unwrap_or_else(global_pool_handle);
    let lanes = pool.lanes();
    // tensor-parallel width: absent keeps the unsharded towers; present
    // serves mlp/transformer through `tp` shard sets (bits invariant
    // across widths — DESIGN.md §13)
    let tp = match parse_tp(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("serve: {e}");
            return 2;
        }
    };
    // pick the model tower (ISSUE 5): the linear reference server, the
    // off-tape MLP, or the off-tape transformer — all behind ModelTower
    let seed = args.get_u64("seed", 5);
    let mut e7_ok = true;
    let tower: Arc<dyn ModelTower> = match model.as_str() {
        "linear" => {
            if tp.is_some() {
                eprintln!(
                    "serve: --tp applies to --model mlp|transformer (the linear \
                     reference server has no shard plan)"
                );
                return 2;
            }
            let w = repdl::rng::uniform_tensor(&[d, 16], -0.3, 0.3, seed);
            let srv = match DeterministicServer::new(w, 16) {
                Ok(s) => Arc::new(s),
                Err(e) => {
                    eprintln!("serve: {e}");
                    return 1;
                }
            };
            // E7 batch-invariance report vs the size-dispatching
            // baseline (meaningful for the GEMM server only)
            let queue: Vec<Tensor> = (0..n)
                .map(|i| repdl::rng::uniform_tensor(&[d], -1.0, 1.0, 100 + i as u64))
                .collect();
            let p = PlatformProfile::zoo()[4];
            let rep = srv
                .batch_invariance_report(&queue, &[1, 4, 16, 64], &p)
                .expect("report");
            println!(
                "requests={} repro_mismatches={} baseline_mismatches={}",
                rep.requests, rep.repro_mismatches, rep.baseline_mismatches
            );
            e7_ok = rep.repro_mismatches == 0;
            // single-caller throughput through the persistent pool
            let t = srv.throughput_report(&pool, &queue, 5).expect("throughput");
            println!("pool_lanes={lanes} throughput={:.0} req/s", t.req_per_s);
            srv
        }
        "mlp" => {
            let hidden = args.get_usize("hidden", 64);
            // user-supplied hyper-parameters: error + exit, never a
            // panic backtrace (same policy as the linear arm) — an
            // indivisible width under --tp lands here too
            let mlp = Mlp::new(&[d, hidden, 16], Act::Gelu, seed);
            let built = match tp {
                Some(tp) => {
                    ShardedTower::mlp(mlp, tp).map(|t| Arc::new(t) as Arc<dyn ModelTower>)
                }
                None => MlpTower::new(mlp).map(|t| Arc::new(t) as Arc<dyn ModelTower>),
            };
            match built {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("serve: {e}");
                    return 1;
                }
            }
        }
        "transformer" => {
            let cfg = repdl::nn::TransformerConfig {
                vocab: 28,
                dim: args.get_usize("width", 32),
                heads: args.get_usize("heads", 4),
                layers: args.get_usize("layers", 2),
                context: args.get_usize("context", 16),
                mlp_ratio: 2,
            };
            // --tp composes with --sessions (the sharded KV cache keeps
            // the full unsharded head layout) and with --journal: both
            // towers share model_id and weights_hash, but an indivisible
            // head count under --tp is an error here, not a panic
            let built = match tp {
                Some(tp) => CharTransformer::new(cfg, seed)
                    .and_then(|m| ShardedTower::transformer(m, tp))
                    .map(|t| Arc::new(t.with_sessions(session_capacity)) as Arc<dyn ModelTower>),
                None => CharTransformer::new(cfg, seed)
                    .and_then(TransformerTower::new)
                    .map(|t| Arc::new(t.with_sessions(session_capacity)) as Arc<dyn ModelTower>),
            };
            match built {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("serve: {e}");
                    return 1;
                }
            }
        }
        other => {
            eprintln!("unknown --model {other} (want linear|mlp|transformer)");
            return 2;
        }
    };
    println!(
        "model={} d_in={} d_out={} weights_hash={}",
        tower.model_id(),
        tower.d_in(),
        tower.d_out(),
        &tower.weights_hash()[..16]
    );
    if let Some(tp) = tp {
        println!("tensor_parallel tp={tp}");
    }
    // request queue in the tower's input domain
    let queue: Vec<Tensor> = if tower.model_id() == "transformer" && session_capacity > 0 {
        // decode-stream queue: request i is a growing prefix of stream
        // i / context — the incremental pattern the session store serves
        // with one O(T) step per extension instead of an O(T²) recompute
        let context = tower.d_in();
        (0..n)
            .map(|i| {
                let (k, tt) = (i / context, i % context + 1);
                let ids: Vec<f32> =
                    (0..tt).map(|t| ((k * 31 + t * 7 + 3) % 28) as f32).collect();
                Tensor::from_vec(&[tt], ids).expect("request")
            })
            .collect()
    } else if tower.model_id() == "transformer" {
        let context = tower.d_in();
        (0..n)
            .map(|i| {
                let ids: Vec<f32> = (0..context)
                    .map(|j| ((i * 31 + j * 7 + 3) % 28) as f32)
                    .collect();
                Tensor::from_vec(&[context], ids).expect("request")
            })
            .collect()
    } else {
        (0..n)
            .map(|i| repdl::rng::uniform_tensor(&[tower.d_in()], -1.0, 1.0, 100 + i as u64))
            .collect()
    };
    // deterministic dynamic-batching scheduler: `clients` concurrent
    // submitters over `shards` replicas sharing one pool — per-request
    // bits must equal the single-caller reference exactly
    let reference = tower.forward_batch(&pool, &queue).expect("reference");
    // open the journal before the scheduler exists: --recover first
    // repairs any torn tail in place (read_journal), then the scheduler
    // appends onto the intact record boundary
    let mut readout = None;
    let journal = match &journal_path {
        Some(path) => {
            if do_recover {
                match read_journal(path) {
                    Ok(r) => {
                        if r.truncated_tail() {
                            println!("journal torn_bytes={} (tail repaired)", r.torn_bytes);
                        }
                        readout = Some(r);
                    }
                    Err(e) => {
                        eprintln!("serve: {e}");
                        return 1;
                    }
                }
            }
            match Journal::open_append(path, journal_policy) {
                Ok(j) => {
                    if !j.is_fresh() && !do_recover {
                        eprintln!(
                            "serve: journal {} already holds records — pass --recover to \
                             rebuild from it (or point --journal at a fresh path)",
                            path.display()
                        );
                        return 2;
                    }
                    Some(Arc::new(j))
                }
                Err(e) => {
                    eprintln!("serve: {e}");
                    return 1;
                }
            }
        }
        None => None,
    };
    let recovering = readout.as_ref().is_some_and(|r| !r.events.is_empty());
    let cfg = ServeConfig {
        batch_window: window,
        max_queue_depth,
        cache_capacity,
        log: do_replay || recovering,
        journal,
        flush_every,
    };
    let sched = ServeScheduler::sharded_with(Arc::clone(&tower), shards, pool, cfg)
        .expect("scheduler");
    let mut recover_ok = true;
    if recovering {
        match sched.recover(readout.as_ref().expect("readout present when recovering")) {
            Ok(rep) => {
                println!(
                    "recovery submits={} restored={} re_executed={} failed_skipped={} \
                     mismatches={} next_ticket={} watermark={} consistent={}",
                    rep.submits,
                    rep.responses_restored,
                    rep.re_executed,
                    rep.failed_skipped,
                    rep.restore_mismatches,
                    rep.next_ticket,
                    rep.watermark,
                    rep.consistent()
                );
                recover_ok = rep.consistent();
            }
            Err(e) => {
                eprintln!("recover failed: {e}");
                return 1;
            }
        }
    }
    // --listen: hand the scheduler to the TCP front end and serve until
    // the process is killed (the CI smoke SIGKILLs it mid-flight; the
    // journal's crash consistency is exactly what recovery then proves)
    if let Some(listen) = listen {
        use repdl::coordinator::{ModelRegistry, NetServer};
        let model_id = tower.model_id().to_string();
        let mut reg = ModelRegistry::new();
        if let Err(e) = reg.register(sched) {
            eprintln!("serve: {e}");
            return 1;
        }
        let reg = Arc::new(reg);
        let _server = match NetServer::bind(Arc::clone(&reg), &listen) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve: listen {listen}: {e}");
                return 1;
            }
        };
        println!("listening addr={} model={model_id}", _server.local_addr());
        // the "listening" line must reach a piped stdout before a
        // two-process driver starts its client
        use std::io::Write;
        let _ = std::io::stdout().flush();
        loop {
            std::thread::park();
        }
    }
    let t0 = std::time::Instant::now();
    let mismatch = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let (sched, queue, reference) = (&sched, &queue, &reference);
                s.spawn(move || {
                    sched
                        .replay_slice(queue, c, clients)
                        .expect("replay")
                        .into_iter()
                        .filter(|(i, out)| !out.bit_eq(&reference[*i]))
                        .count()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "scheduler shards={shards} batch_window={window} clients={clients} \
         mismatches={mismatch} throughput={:.0} req/s",
        n as f64 / elapsed.max(1e-9)
    );
    if let Some(depth) = max_queue_depth {
        println!(
            "admission max_queue_depth={depth} rejected={} in_flight={}",
            sched.rejected(),
            sched.in_flight()
        );
    }
    if let Some(cs) = sched.cache_stats() {
        println!(
            "cache capacity={} hits={} misses={} evictions={} held={}",
            cs.capacity, cs.hits, cs.misses, cs.evictions, cs.len
        );
    }
    if let Some(ss) = sched.session_stats() {
        println!(
            "sessions capacity={} hits={} misses={} evictions={} held={}",
            ss.capacity, ss.hits, ss.misses, ss.evictions, ss.len
        );
    }
    let replay_ok = if do_replay {
        // re-execute the whole logged ticket range and verify bit-exactly
        match sched.replay(0..u64::MAX) {
            Ok(rep) => {
                println!(
                    "replay replayed={} response_mismatches={} request_mismatches={} verified={}",
                    rep.replayed,
                    rep.response_mismatches,
                    rep.request_mismatches,
                    rep.verified()
                );
                rep.verified()
            }
            Err(e) => {
                eprintln!("replay failed: {e}");
                false
            }
        }
    } else {
        true
    };
    // explicit journal barrier before exit so a sync failure is a loud
    // nonzero exit, not something the drop path swallows; the drop-time
    // sync then finds nothing left to do
    let journal_ok = match sched.sync_journal() {
        Ok(()) => true,
        Err(e) => {
            eprintln!("journal sync failed: {e}");
            false
        }
    };
    if let Some(js) = sched.journal_stats() {
        println!(
            "journal appends={} buffered={} drops={} failed={}",
            js.appends, js.buffered, js.drops, js.failed
        );
    }
    if e7_ok && mismatch == 0 && replay_ok && recover_ok && journal_ok {
        0
    } else {
        1
    }
}

/// Remote client for a `serve --listen` server: generates the same
/// deterministic request queue `cmd_serve`'s in-process loop uses
/// (shapes come from the server's hello, never guessed), pipelines it,
/// publishes a flush cut, and prints each response's ticket and bit
/// hash — so two runs against bit-identical servers print bit-identical
/// lines, which is what the CI kill-and-recover smoke greps.
fn cmd_request(args: &Args) -> i32 {
    use repdl::coordinator::NetClient;
    let addr = match args.get_opt_str("connect") {
        Some(a) => a,
        None => {
            eprintln!("request: --connect HOST:PORT is required");
            return 2;
        }
    };
    let model = args.get_str("model", "linear");
    let n = args.get_usize("requests", 8);
    let mut client = match NetClient::connect(&addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("request: connect {addr}: {e}");
            return 1;
        }
    };
    let info = match client.model(&model) {
        Some(m) => m.clone(),
        None => {
            let served: Vec<&str> =
                client.models().iter().map(|m| m.model_id.as_str()).collect();
            eprintln!("request: server does not serve '{model}' (serves: {served:?})");
            return 2;
        }
    };
    println!(
        "connected model={} d_in={} d_out={} weights_hash={}",
        info.model_id,
        info.d_in,
        info.d_out,
        &info.weights_hash[..16.min(info.weights_hash.len())]
    );
    let d_in = info.d_in as usize;
    // the same deterministic queue cmd_serve generates in-process, so a
    // remote run is bit-comparable to a local one
    let queue: Vec<Tensor> = if model == "transformer" {
        (0..n)
            .map(|i| {
                let ids: Vec<f32> =
                    (0..d_in).map(|j| ((i * 31 + j * 7 + 3) % 28) as f32).collect();
                Tensor::from_vec(&[d_in], ids).expect("request")
            })
            .collect()
    } else {
        (0..n)
            .map(|i| repdl::rng::uniform_tensor(&[d_in], -1.0, 1.0, 100 + i as u64))
            .collect()
    };
    for r in &queue {
        if let Err(e) = client.send_request(&model, r) {
            eprintln!("request: {e}");
            return 1;
        }
    }
    if let Err(e) = client.send_flush(&model) {
        eprintln!("request: {e}");
        return 1;
    }
    for i in 0..n {
        match client.recv_response() {
            Ok((_req_id, ticket, out)) => {
                println!("response {i} ticket={ticket} hash={}", out.bit_hash_hex());
            }
            Err(e) => {
                eprintln!("request: response {i}: {e}");
                return 1;
            }
        }
    }
    if let Err(e) = client.recv_flushed() {
        eprintln!("request: {e}");
        return 1;
    }
    match client.stats(&model) {
        Ok((next_ticket, in_flight, rejected, journal_appends)) => {
            println!(
                "stats next_ticket={next_ticket} in_flight={in_flight} \
                 rejected={rejected} journal_appends={journal_appends}"
            );
        }
        Err(e) => {
            eprintln!("request: stats: {e}");
            return 1;
        }
    }
    if let Err(e) = client.bye() {
        eprintln!("request: {e}");
        return 1;
    }
    0
}

fn cmd_runtime(args: &Args) -> i32 {
    let dir = args.get_str("artifacts", "artifacts");
    let name = args.get_str("name", "matmul_repro");
    let mut rt = match repdl::runtime::Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("platform: {}", rt.platform());
    let spec = match rt.specs.get(&name) {
        Some(s) => s.clone(),
        None => {
            eprintln!("unknown artifact '{name}'");
            return 2;
        }
    };
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| repdl::rng::uniform_tensor(&s.dims, -1.0, 1.0, 31 + i as u64))
        .collect();
    match rt.run(&name, &inputs) {
        Ok(outs) => {
            for (i, o) in outs.iter().enumerate() {
                println!("output {i}: shape {:?} hash {}", o.dims(), o.bit_hash_hex());
            }
            0
        }
        Err(e) => {
            eprintln!("execute failed: {e}");
            1
        }
    }
}

fn cmd_selftest() -> i32 {
    use repdl::rnum::{rexp, rlog, rsin, rtanh};
    let checks: [(&str, bool); 4] = [
        ("exp determinism", rexp(1.5).to_bits() == rexp(1.5).to_bits()),
        ("log(exp(1)) ≈ 1", (rlog(rexp(1.0)) - 1.0).abs() < 1e-6),
        ("sin(π/6) ≈ 0.5", (rsin(std::f32::consts::FRAC_PI_6) - 0.5).abs() < 1e-6),
        ("tanh odd", rtanh(0.7) == -rtanh(-0.7)),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("{} {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    if ok {
        0
    } else {
        1
    }
}
