//! Minimal deterministic property-testing harness.
//!
//! The `proptest` crate is not in the offline crate set (DESIGN.md §5),
//! so this module provides the subset we need: seeded generators, a
//! `forall` runner with case reporting, and f32 generators that cover the
//! nasty regions (subnormals, near-overflow, signed zero, exact powers of
//! two). Deterministic by construction — a failing case always reports
//! the (seed, index) needed to replay it.

/// SplitMix64 generator for test inputs.
pub struct Gen {
    state: u64,
}

impl Gen {
    /// New generator.
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// Next u64.
    pub fn u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.u64() % n.max(1) as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    /// "Any finite f32", biased toward hard regions: uniform bits
    /// filtered to finite, mixed with specials.
    pub fn f32_any(&mut self) -> f32 {
        match self.u64() % 8 {
            0 => {
                // exact special values
                const SPECIALS: [f32; 8] = [0.0, -0.0, 1.0, -1.0, 0.5, 2.0, 16_777_216.0, 1e-39];
                SPECIALS[(self.u64() % 8) as usize]
            }
            1 => f32::from_bits((self.u64() as u32) & 0x007f_ffff), // subnormal
            2 => {
                // near overflow
                f32::from_bits(0x7f00_0000 | (self.u64() as u32 & 0x7f_ffff))
            }
            _ => loop {
                let v = f32::from_bits(self.u64() as u32);
                if v.is_finite() {
                    return v;
                }
            },
        }
    }

    /// Vector of moderate-magnitude floats.
    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(-scale, scale)).collect()
    }

    /// Like [`Gen::f32_vec`], but roughly one slot in `every` becomes a
    /// quiet NaN with a **random payload** — for pinning the canonical
    /// tie/NaN comparison rule (`tensor::reduce::max_wins`), where
    /// "which NaN won" is observable through its payload bits.
    pub fn f32_vec_nan_laced(&mut self, n: usize, scale: f32, every: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if self.u64() % every.max(1) as u64 == 0 {
                    f32::from_bits(0x7fc0_0000 | (self.u64() as u32 & 0x003f_ffff))
                } else {
                    self.f32_range(-scale, scale)
                }
            })
            .collect()
    }
}

/// Run `cases` checks of `prop` over generated inputs; panic with the
/// replay coordinates on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
    T: std::fmt::Debug,
{
    let mut g = Gen::new(seed);
    for i in 0..cases {
        let input = gen(&mut g);
        if !prop(&input) {
            panic!("property failed at seed={seed} case={i}: input={input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let a: Vec<u64> = { let mut g = Gen::new(1); (0..10).map(|_| g.u64()).collect() };
        let b: Vec<u64> = { let mut g = Gen::new(1); (0..10).map(|_| g.u64()).collect() };
        assert_eq!(a, b);
    }

    #[test]
    fn f32_any_hits_subnormals_and_normals() {
        let mut g = Gen::new(2);
        let mut subnormal = false;
        let mut big = false;
        for _ in 0..1000 {
            let v = g.f32_any();
            assert!(v.is_finite());
            subnormal |= crate::rnum::fbits::is_subnormal(v);
            big |= v.abs() > 1e30;
        }
        assert!(subnormal && big);
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall(3, 100, |g| g.f32_range(0.0, 1.0), |&x| (0.0..1.0).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(4, 100, |g| g.below(10), |&x| x < 5);
    }

    // ---- kernel conformance properties (pool + blocked GEMM) ----

    use crate::rnum::sum::{sum_pairwise, sum_sequential};
    use crate::tensor::{
        matmul_dotform_in, matmul_fma_in, matmul_in, sum_axis_in, sum_axis_pairwise_in, Tensor,
        WorkerPool,
    };

    #[test]
    fn prop_blocked_gemm_equals_dotform_bitwise() {
        // randomized shapes straddle the blocked kernel's tile
        // boundaries; loop interchange/blocking must never move a bit
        let pool = WorkerPool::new(3);
        forall(
            11,
            40,
            |g| {
                let m = 1 + g.below(12);
                let k = 1 + g.below(48);
                let n = 1 + g.below(300);
                let a = g.f32_vec(m * k, 2.0);
                let b = g.f32_vec(k * n, 2.0);
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let at = Tensor::from_vec(&[*m, *k], a.clone()).unwrap();
                let bt = Tensor::from_vec(&[*k, *n], b.clone()).unwrap();
                let blocked = matmul_in(&pool, &at, &bt).unwrap();
                let dotform = matmul_dotform_in(&pool, &at, &bt).unwrap();
                blocked.bit_eq(&dotform)
            },
        );
    }

    #[test]
    fn prop_gemm_pool_size_invariant() {
        let one = WorkerPool::new(1);
        let seven = WorkerPool::new(7);
        forall(
            13,
            30,
            |g| {
                let m = 1 + g.below(20);
                let k = 1 + g.below(30);
                let n = 1 + g.below(40);
                let a = g.f32_vec(m * k, 3.0);
                let b = g.f32_vec(k * n, 3.0);
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let at = Tensor::from_vec(&[*m, *k], a.clone()).unwrap();
                let bt = Tensor::from_vec(&[*k, *n], b.clone()).unwrap();
                matmul_in(&one, &at, &bt)
                    .unwrap()
                    .bit_eq(&matmul_in(&seven, &at, &bt).unwrap())
                    && matmul_fma_in(&one, &at, &bt)
                        .unwrap()
                        .bit_eq(&matmul_fma_in(&seven, &at, &bt).unwrap())
            },
        );
    }

    #[test]
    fn prop_pooled_reduce_equals_rnum_sums_bitwise() {
        // the tensor-level pooled reduction must reproduce the scalar
        // rnum specifications exactly, element for element
        let pool = WorkerPool::new(4);
        forall(
            17,
            60,
            |g| {
                let n = 1 + g.below(2000);
                g.f32_vec(n, 100.0)
            },
            |xs| {
                let t = Tensor::from_vec(&[xs.len()], xs.clone()).unwrap();
                let seq = sum_axis_in(&pool, &t, 0).unwrap().data()[0];
                let pw = sum_axis_pairwise_in(&pool, &t, 0).unwrap().data()[0];
                seq.to_bits() == sum_sequential(xs).to_bits()
                    && pw.to_bits() == sum_pairwise(xs).to_bits()
            },
        );
    }

    // ---- NaN-rule unification properties (DESIGN.md §8 migration) ----

    use crate::nn::{log_softmax_rows, softmax_rows};
    use crate::rnum::{rexp, rlog};
    use crate::tensor::{max_axis, max_pool2d};

    #[test]
    fn prop_softmax_row_max_agrees_with_max_axis() {
        // the migrated softmax/log-softmax row max shares max_wins with
        // max_axis: rebuilding each fixed graph from the max_axis row max
        // must reproduce every output bit — NaN-laced (random payloads)
        // and all-NaN rows included
        forall(
            23,
            60,
            |g| {
                let rows = 1 + g.below(4);
                let cols = 1 + g.below(12);
                let mut xs = g.f32_vec_nan_laced(rows * cols, 8.0, 5);
                if g.below(3) == 0 {
                    // force one all-NaN row (payloads still vary)
                    let r = g.below(rows);
                    for v in &mut xs[r * cols..(r + 1) * cols] {
                        *v = f32::from_bits(0x7fc0_0000 | (g.u64() as u32 & 0x003f_ffff));
                    }
                }
                (rows, cols, xs)
            },
            |(rows, cols, xs)| {
                let t = Tensor::from_vec(&[*rows, *cols], xs.clone()).unwrap();
                let m = max_axis(&t, 1).unwrap();
                let s = softmax_rows(&t).unwrap();
                let ls = log_softmax_rows(&t).unwrap();
                (0..*rows).all(|r| {
                    let mm = m.data()[r];
                    let w = &xs[r * cols..(r + 1) * cols];
                    let mut es = vec![0.0f32; *cols];
                    let mut denom = 0.0f32;
                    for j in 0..*cols {
                        es[j] = rexp(w[j] - mm);
                        denom += es[j];
                    }
                    let lse = rlog(denom);
                    (0..*cols).all(|j| {
                        s.data()[r * cols + j].to_bits() == (es[j] / denom).to_bits()
                            && ls.data()[r * cols + j].to_bits()
                                == (w[j] - mm - lse).to_bits()
                    })
                })
            },
        );
    }

    #[test]
    fn prop_max_pool_window_max_agrees_with_max_axis() {
        // every pooled output must hold exactly the bits max_axis returns
        // for that window flattened in the kernel's (di, dj) scan order —
        // the two scans share max_wins, so NaN payloads and tie choices
        // must match too
        forall(
            29,
            40,
            |g| {
                let b = 1 + g.below(2);
                let c = 1 + g.below(3);
                let k = 1 + g.below(3);
                let (oh, ow) = (1 + g.below(3), 1 + g.below(3));
                let (h, w) = (oh * k, ow * k);
                (b, c, h, w, k, g.f32_vec_nan_laced(b * c * h * w, 8.0, 4))
            },
            |(b, c, h, w, k, xs)| {
                let t = Tensor::from_vec(&[*b, *c, *h, *w], xs.clone()).unwrap();
                let p = max_pool2d(&t, *k).unwrap();
                let (oh, ow) = (h / k, w / k);
                (0..b * c * oh * ow).all(|e| {
                    let (bc, i, j) = (e / (oh * ow), (e / ow) % oh, e % ow);
                    let base = bc * h * w + i * k * w + j * k;
                    let win: Vec<f32> = (0..*k)
                        .flat_map(|di| (0..*k).map(move |dj| xs[base + di * w + dj]))
                        .collect();
                    let wt = Tensor::from_vec(&[1, k * k], win).unwrap();
                    let m = max_axis(&wt, 1).unwrap().data()[0];
                    p.data()[e].to_bits() == m.to_bits()
                })
            },
        );
    }

    #[test]
    fn prop_pooled_rowwise_reduce_matches_scalar_spec() {
        // 2-D last-axis reduction: every output row equals the rnum
        // scalar sum of that row, for a pool larger than the row count
        let pool = WorkerPool::new(8);
        forall(
            19,
            40,
            |g| {
                let rows = 1 + g.below(6);
                let cols = 1 + g.below(200);
                (rows, cols, g.f32_vec(rows * cols, 10.0))
            },
            |(rows, cols, xs)| {
                let t = Tensor::from_vec(&[*rows, *cols], xs.clone()).unwrap();
                let s = sum_axis_in(&pool, &t, 1).unwrap();
                (0..*rows).all(|r| {
                    s.data()[r].to_bits()
                        == sum_sequential(&xs[r * cols..(r + 1) * cols]).to_bits()
                })
            },
        );
    }
}
