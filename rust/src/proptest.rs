//! Minimal deterministic property-testing harness.
//!
//! The `proptest` crate is not in the offline crate set (DESIGN.md §5),
//! so this module provides the subset we need: seeded generators, a
//! `forall` runner with case reporting, and f32 generators that cover the
//! nasty regions (subnormals, near-overflow, signed zero, exact powers of
//! two). Deterministic by construction — a failing case always reports
//! the (seed, index) needed to replay it.

/// SplitMix64 generator for test inputs.
pub struct Gen {
    state: u64,
}

impl Gen {
    /// New generator.
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// Next u64.
    pub fn u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.u64() % n.max(1) as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    /// "Any finite f32", biased toward hard regions: uniform bits
    /// filtered to finite, mixed with specials.
    pub fn f32_any(&mut self) -> f32 {
        match self.u64() % 8 {
            0 => {
                // exact special values
                const SPECIALS: [f32; 8] = [0.0, -0.0, 1.0, -1.0, 0.5, 2.0, 16_777_216.0, 1e-39];
                SPECIALS[(self.u64() % 8) as usize]
            }
            1 => f32::from_bits((self.u64() as u32) & 0x007f_ffff), // subnormal
            2 => {
                // near overflow
                f32::from_bits(0x7f00_0000 | (self.u64() as u32 & 0x7f_ffff))
            }
            _ => loop {
                let v = f32::from_bits(self.u64() as u32);
                if v.is_finite() {
                    return v;
                }
            },
        }
    }

    /// Vector of moderate-magnitude floats.
    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(-scale, scale)).collect()
    }
}

/// Run `cases` checks of `prop` over generated inputs; panic with the
/// replay coordinates on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
    T: std::fmt::Debug,
{
    let mut g = Gen::new(seed);
    for i in 0..cases {
        let input = gen(&mut g);
        if !prop(&input) {
            panic!("property failed at seed={seed} case={i}: input={input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let a: Vec<u64> = { let mut g = Gen::new(1); (0..10).map(|_| g.u64()).collect() };
        let b: Vec<u64> = { let mut g = Gen::new(1); (0..10).map(|_| g.u64()).collect() };
        assert_eq!(a, b);
    }

    #[test]
    fn f32_any_hits_subnormals_and_normals() {
        let mut g = Gen::new(2);
        let mut subnormal = false;
        let mut big = false;
        for _ in 0..1000 {
            let v = g.f32_any();
            assert!(v.is_finite());
            subnormal |= crate::rnum::fbits::is_subnormal(v);
            big |= v.abs() > 1e30;
        }
        assert!(subnormal && big);
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall(3, 100, |g| g.f32_range(0.0, 1.0), |&x| (0.0..1.0).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(4, 100, |g| g.below(10), |&x| x < 5);
    }
}
