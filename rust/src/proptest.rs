//! Minimal deterministic property-testing harness.
//!
//! The `proptest` crate is not in the offline crate set (DESIGN.md §5),
//! so this module provides the subset we need: seeded generators, a
//! `forall` runner with case reporting, and f32 generators that cover the
//! nasty regions (subnormals, near-overflow, signed zero, exact powers of
//! two). Deterministic by construction — a failing case always reports
//! the (seed, index) needed to replay it.

/// SplitMix64 generator for test inputs.
pub struct Gen {
    state: u64,
}

impl Gen {
    /// New generator.
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// Next u64.
    pub fn u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.u64() % n.max(1) as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    /// "Any finite f32", biased toward hard regions: uniform bits
    /// filtered to finite, mixed with specials.
    pub fn f32_any(&mut self) -> f32 {
        match self.u64() % 8 {
            0 => {
                // exact special values
                const SPECIALS: [f32; 8] = [0.0, -0.0, 1.0, -1.0, 0.5, 2.0, 16_777_216.0, 1e-39];
                SPECIALS[(self.u64() % 8) as usize]
            }
            1 => f32::from_bits((self.u64() as u32) & 0x007f_ffff), // subnormal
            2 => {
                // near overflow
                f32::from_bits(0x7f00_0000 | (self.u64() as u32 & 0x7f_ffff))
            }
            _ => loop {
                let v = f32::from_bits(self.u64() as u32);
                if v.is_finite() {
                    return v;
                }
            },
        }
    }

    /// Vector of moderate-magnitude floats.
    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(-scale, scale)).collect()
    }
}

/// Run `cases` checks of `prop` over generated inputs; panic with the
/// replay coordinates on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
    T: std::fmt::Debug,
{
    let mut g = Gen::new(seed);
    for i in 0..cases {
        let input = gen(&mut g);
        if !prop(&input) {
            panic!("property failed at seed={seed} case={i}: input={input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let a: Vec<u64> = { let mut g = Gen::new(1); (0..10).map(|_| g.u64()).collect() };
        let b: Vec<u64> = { let mut g = Gen::new(1); (0..10).map(|_| g.u64()).collect() };
        assert_eq!(a, b);
    }

    #[test]
    fn f32_any_hits_subnormals_and_normals() {
        let mut g = Gen::new(2);
        let mut subnormal = false;
        let mut big = false;
        for _ in 0..1000 {
            let v = g.f32_any();
            assert!(v.is_finite());
            subnormal |= crate::rnum::fbits::is_subnormal(v);
            big |= v.abs() > 1e30;
        }
        assert!(subnormal && big);
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall(3, 100, |g| g.f32_range(0.0, 1.0), |&x| (0.0..1.0).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(4, 100, |g| g.below(10), |&x| x < 5);
    }

    // ---- kernel conformance properties (pool + blocked GEMM) ----

    use crate::rnum::sum::{sum_pairwise, sum_sequential};
    use crate::tensor::{
        matmul_dotform_in, matmul_fma_in, matmul_in, sum_axis_in, sum_axis_pairwise_in, Tensor,
        WorkerPool,
    };

    #[test]
    fn prop_blocked_gemm_equals_dotform_bitwise() {
        // randomized shapes straddle the blocked kernel's tile
        // boundaries; loop interchange/blocking must never move a bit
        let pool = WorkerPool::new(3);
        forall(
            11,
            40,
            |g| {
                let m = 1 + g.below(12);
                let k = 1 + g.below(48);
                let n = 1 + g.below(300);
                let a = g.f32_vec(m * k, 2.0);
                let b = g.f32_vec(k * n, 2.0);
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let at = Tensor::from_vec(&[*m, *k], a.clone()).unwrap();
                let bt = Tensor::from_vec(&[*k, *n], b.clone()).unwrap();
                let blocked = matmul_in(&pool, &at, &bt).unwrap();
                let dotform = matmul_dotform_in(&pool, &at, &bt).unwrap();
                blocked.bit_eq(&dotform)
            },
        );
    }

    #[test]
    fn prop_gemm_pool_size_invariant() {
        let one = WorkerPool::new(1);
        let seven = WorkerPool::new(7);
        forall(
            13,
            30,
            |g| {
                let m = 1 + g.below(20);
                let k = 1 + g.below(30);
                let n = 1 + g.below(40);
                let a = g.f32_vec(m * k, 3.0);
                let b = g.f32_vec(k * n, 3.0);
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let at = Tensor::from_vec(&[*m, *k], a.clone()).unwrap();
                let bt = Tensor::from_vec(&[*k, *n], b.clone()).unwrap();
                matmul_in(&one, &at, &bt)
                    .unwrap()
                    .bit_eq(&matmul_in(&seven, &at, &bt).unwrap())
                    && matmul_fma_in(&one, &at, &bt)
                        .unwrap()
                        .bit_eq(&matmul_fma_in(&seven, &at, &bt).unwrap())
            },
        );
    }

    #[test]
    fn prop_pooled_reduce_equals_rnum_sums_bitwise() {
        // the tensor-level pooled reduction must reproduce the scalar
        // rnum specifications exactly, element for element
        let pool = WorkerPool::new(4);
        forall(
            17,
            60,
            |g| {
                let n = 1 + g.below(2000);
                g.f32_vec(n, 100.0)
            },
            |xs| {
                let t = Tensor::from_vec(&[xs.len()], xs.clone()).unwrap();
                let seq = sum_axis_in(&pool, &t, 0).unwrap().data()[0];
                let pw = sum_axis_pairwise_in(&pool, &t, 0).unwrap().data()[0];
                seq.to_bits() == sum_sequential(xs).to_bits()
                    && pw.to_bits() == sum_pairwise(xs).to_bits()
            },
        );
    }

    #[test]
    fn prop_pooled_rowwise_reduce_matches_scalar_spec() {
        // 2-D last-axis reduction: every output row equals the rnum
        // scalar sum of that row, for a pool larger than the row count
        let pool = WorkerPool::new(8);
        forall(
            19,
            40,
            |g| {
                let rows = 1 + g.below(6);
                let cols = 1 + g.below(200);
                (rows, cols, g.f32_vec(rows * cols, 10.0))
            },
            |(rows, cols, xs)| {
                let t = Tensor::from_vec(&[*rows, *cols], xs.clone()).unwrap();
                let s = sum_axis_in(&pool, &t, 1).unwrap();
                (0..*rows).all(|r| {
                    s.data()[r].to_bits()
                        == sum_sequential(&xs[r * cols..(r + 1) * cols]).to_bits()
                })
            },
        );
    }
}
