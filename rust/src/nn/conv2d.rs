//! `nn::Conv2d` — module wrapper over the reproducible convolution.

use super::Module;
use crate::autograd::{Tape, Var};
use crate::rng::{derive_seed, kaiming_uniform, uniform_tensor};
use crate::rnum::rrsqrt;
use crate::tensor::{Conv2dParams, Tensor};
use crate::Result;

/// 2-D convolution layer (OIHW weights, NCHW activations).
pub struct Conv2d {
    /// Weight (O, C, KH, KW).
    pub weight: Tensor,
    /// Bias (O,).
    pub bias: Tensor,
    /// Stride/padding.
    pub params: Conv2dParams,
}

impl Conv2d {
    /// PyTorch-default init.
    pub fn new(
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        params: Conv2dParams,
        seed: u64,
    ) -> Self {
        let weight = kaiming_uniform(&[out_ch, in_ch, kernel, kernel], derive_seed(seed, 0));
        let fan_in = (in_ch * kernel * kernel) as f32;
        let bound = rrsqrt(fan_in);
        let bias = uniform_tensor(&[out_ch], -bound, bound, derive_seed(seed, 1));
        Conv2d { weight, bias, params }
    }
}

impl Module for Conv2d {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let w = t.param(self.weight.clone());
        let b = t.param(self.bias.clone());
        binds.push(w);
        binds.push(b);
        t.conv2d(x, w, Some(b), self.params)
    }

    fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight, &mut self.bias]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape_and_grads() {
        let c = Conv2d::new(3, 8, 3, Conv2dParams { stride: 1, padding: 1 }, 7);
        assert_eq!(c.weight.dims(), &[8, 3, 3, 3]);
        let x = Tensor::full(&[2, 3, 6, 6], 0.1);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let y = c.forward(&mut t, xv, &mut binds).unwrap();
        assert_eq!(t.value_ref(y).dims(), &[2, 8, 6, 6]);
        let loss = t.mean_all(y);
        t.backward(loss).unwrap();
        assert_eq!(t.grad(binds[0]).unwrap().dims(), &[8, 3, 3, 3]);
        assert_eq!(t.grad(binds[1]).unwrap().dims(), &[8]);
    }

    #[test]
    fn init_reproducible() {
        let a = Conv2d::new(2, 4, 3, Conv2dParams::default(), 5);
        let b = Conv2d::new(2, 4, 3, Conv2dParams::default(), 5);
        assert!(a.weight.bit_eq(&b.weight));
        assert!(a.bias.bit_eq(&b.bias));
    }
}
