//! Batch normalisation — the paper's flagship §3.2.3 example.
//!
//! PyTorch documents one formula, but backends implement (at least) three
//! different computation orders that are equal over the reals and
//! *different* in floating point. RepDL's rule: **each computation graph
//! is a separate API**. The three variants here are exactly the paper's:
//!
//! * [`batch_norm`]          — `(x − μ) / √(σ² + ε) · w + b`
//! * [`batch_norm_folded`]   — `(w / √(σ² + ε)) · (x − μ) + b`
//! * [`batch_norm_affine_folded`] — `s·x + (b − μ·s)`, `s = w/√(σ²+ε)`
//!
//! Experiment E9 shows they differ bitwise from one another while each is
//! individually reproducible.

use crate::rnum::{rrsqrt, rsqrt_f32};
use crate::tensor::Tensor;
use crate::{Error, Result};

fn check_bn(x: &Tensor, c: usize, name: &str) -> Result<()> {
    let d = x.dims();
    if d.len() != 4 || d[1] != c {
        return Err(Error::shape(format!("{name}: want NCHW with C={c}, got {d:?}")));
    }
    Ok(())
}

/// Variant 1 (the documented formula): `(x − μ)/√(σ²+ε) · w + b`.
/// All inputs per-channel; x is NCHW.
pub fn batch_norm(
    x: &Tensor,
    mean: &[f32],
    var: &[f32],
    weight: &[f32],
    bias: &[f32],
    eps: f32,
) -> Result<Tensor> {
    check_bn(x, mean.len(), "batch_norm")?;
    let d = x.dims();
    let (n, c, hw) = (d[0], d[1], d[2] * d[3]);
    let mut out = Tensor::zeros(d);
    for ni in 0..n {
        for ci in 0..c {
            let denom = rsqrt_f32(var[ci] + eps);
            for s in 0..hw {
                let idx = (ni * c + ci) * hw + s;
                let v = (x.data()[idx] - mean[ci]) / denom * weight[ci] + bias[ci];
                out.data_mut()[idx] = v;
            }
        }
    }
    Ok(out)
}

/// Variant 2: fold the scale first — `(w/√(σ²+ε)) · (x − μ) + b`.
pub fn batch_norm_folded(
    x: &Tensor,
    mean: &[f32],
    var: &[f32],
    weight: &[f32],
    bias: &[f32],
    eps: f32,
) -> Result<Tensor> {
    check_bn(x, mean.len(), "batch_norm_folded")?;
    let d = x.dims();
    let (n, c, hw) = (d[0], d[1], d[2] * d[3]);
    let mut out = Tensor::zeros(d);
    for ni in 0..n {
        for ci in 0..c {
            let s = weight[ci] * rrsqrt(var[ci] + eps);
            for k in 0..hw {
                let idx = (ni * c + ci) * hw + k;
                out.data_mut()[idx] = s * (x.data()[idx] - mean[ci]) + bias[ci];
            }
        }
    }
    Ok(out)
}

/// Variant 3: fold scale *and* shift — `s·x + (b − μ·s)`.
pub fn batch_norm_affine_folded(
    x: &Tensor,
    mean: &[f32],
    var: &[f32],
    weight: &[f32],
    bias: &[f32],
    eps: f32,
) -> Result<Tensor> {
    check_bn(x, mean.len(), "batch_norm_affine_folded")?;
    let d = x.dims();
    let (n, c, hw) = (d[0], d[1], d[2] * d[3]);
    let mut out = Tensor::zeros(d);
    for ni in 0..n {
        for ci in 0..c {
            let s = weight[ci] * rrsqrt(var[ci] + eps);
            let shift = bias[ci] - mean[ci] * s;
            for k in 0..hw {
                let idx = (ni * c + ci) * hw + k;
                out.data_mut()[idx] = s * x.data()[idx] + shift;
            }
        }
    }
    Ok(out)
}

/// `nn::BatchNorm2d` module: batch statistics in training mode (with
/// running-stat update, fixed sequential reductions), running statistics
/// in eval mode. Uses the Variant-1 graph.
pub struct BatchNorm2d {
    /// γ (scale), per channel.
    pub weight: Tensor,
    /// β (shift), per channel.
    pub bias: Tensor,
    /// Running mean (eval mode).
    pub running_mean: Tensor,
    /// Running variance (eval mode).
    pub running_var: Tensor,
    /// Numerical epsilon.
    pub eps: f32,
    /// Running-stat momentum (PyTorch convention).
    pub momentum: f32,
}

impl BatchNorm2d {
    /// PyTorch defaults: γ=1, β=0, eps=1e−5, momentum=0.1.
    pub fn new(c: usize) -> Self {
        BatchNorm2d {
            weight: Tensor::full(&[c], 1.0),
            bias: Tensor::zeros(&[c]),
            running_mean: Tensor::zeros(&[c]),
            running_var: Tensor::full(&[c], 1.0),
            eps: 1e-5,
            momentum: 0.1,
        }
    }

    /// Per-channel batch statistics: sequential sums over (N, H, W).
    pub fn batch_stats(&self, x: &Tensor) -> Result<(Vec<f32>, Vec<f32>)> {
        check_bn(x, self.weight.numel(), "batch_stats")?;
        let d = x.dims();
        let (n, c, hw) = (d[0], d[1], d[2] * d[3]);
        let cnt = (n * hw) as f32;
        let mut means = vec![0.0f32; c];
        let mut vars = vec![0.0f32; c];
        for ci in 0..c {
            let mut acc = 0.0f32;
            for ni in 0..n {
                for s in 0..hw {
                    acc += x.data()[(ni * c + ci) * hw + s];
                }
            }
            let mu = acc / cnt;
            means[ci] = mu;
            let mut v2 = 0.0f32;
            for ni in 0..n {
                for s in 0..hw {
                    let dd = x.data()[(ni * c + ci) * hw + s] - mu;
                    v2 += dd * dd;
                }
            }
            vars[ci] = v2 / cnt; // biased, like PyTorch's normalisation
        }
        Ok((means, vars))
    }

    /// Training-mode forward: normalise by batch stats and update the
    /// running statistics (fixed graph: `r = (1−m)·r + m·stat`).
    pub fn forward_train(&mut self, x: &Tensor) -> Result<Tensor> {
        let (mean, var) = self.batch_stats(x)?;
        let m = self.momentum;
        for (i, (&mu, &v)) in mean.iter().zip(var.iter()).enumerate() {
            let rm = self.running_mean.data()[i];
            let rv = self.running_var.data()[i];
            self.running_mean.data_mut()[i] = (1.0 - m) * rm + m * mu;
            self.running_var.data_mut()[i] = (1.0 - m) * rv + m * v;
        }
        batch_norm(x, &mean, &var, self.weight.data(), self.bias.data(), self.eps)
    }

    /// Eval-mode forward: running statistics, Variant-1 graph.
    pub fn forward_eval(&self, x: &Tensor) -> Result<Tensor> {
        batch_norm(
            x,
            self.running_mean.data(),
            self.running_var.data(),
            self.weight.data(),
            self.bias.data(),
            self.eps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(dims: &[usize], seed: u64) -> Tensor {
        let n: usize = dims.iter().product();
        let mut s = seed;
        Tensor::from_vec(
            dims,
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(12345);
                    (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 4.0
                })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn three_graphs_agree_numerically_but_not_bitwise() {
        let x = lcg(&[2, 3, 4, 4], 1);
        let mean = vec![0.1, -0.2, 0.3];
        let var = vec![1.1, 0.9, 1.3];
        let w = vec![1.2, 0.8, 1.0];
        let b = vec![0.01, -0.02, 0.3];
        let v1 = batch_norm(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v2 = batch_norm_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v3 = batch_norm_affine_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        for i in 0..v1.numel() {
            assert!((v1.data()[i] - v2.data()[i]).abs() < 1e-5);
            assert!((v1.data()[i] - v3.data()[i]).abs() < 1e-5);
        }
        // the paper's point: equal in ℝ, different in f32
        assert!(!v1.bit_eq(&v2) || !v1.bit_eq(&v3) || !v2.bit_eq(&v3));
        // and each is individually deterministic
        assert!(v1.bit_eq(&batch_norm(&x, &mean, &var, &w, &b, 1e-5).unwrap()));
        assert!(v2.bit_eq(&batch_norm_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap()));
        assert!(v3.bit_eq(&batch_norm_affine_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap()));
    }

    #[test]
    fn normalises_to_zero_mean_unit_var() {
        let x = lcg(&[4, 2, 8, 8], 2);
        let mut bn = BatchNorm2d::new(2);
        let y = bn.forward_train(&x).unwrap();
        let (mean, var) = bn.batch_stats(&y).unwrap();
        for c in 0..2 {
            assert!(mean[c].abs() < 1e-4, "mean[{c}]={}", mean[c]);
            assert!((var[c] - 1.0).abs() < 1e-3, "var[{c}]={}", var[c]);
        }
    }

    #[test]
    fn running_stats_update() {
        let x = lcg(&[2, 2, 4, 4], 3);
        let mut bn = BatchNorm2d::new(2);
        let (mean, var) = bn.batch_stats(&x).unwrap();
        bn.forward_train(&x).unwrap();
        for c in 0..2 {
            let want_m = 0.9 * 0.0 + 0.1 * mean[c];
            let want_v = 0.9 * 1.0 + 0.1 * var[c];
            assert!((bn.running_mean.data()[c] - want_m).abs() < 1e-6);
            assert!((bn.running_var.data()[c] - want_v).abs() < 1e-6);
        }
    }

    #[test]
    fn eval_mode_is_pure() {
        let x = lcg(&[1, 2, 3, 3], 4);
        let bn = BatchNorm2d::new(2);
        let a = bn.forward_eval(&x).unwrap();
        let b = bn.forward_eval(&x).unwrap();
        assert!(a.bit_eq(&b));
    }
}
