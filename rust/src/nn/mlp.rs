//! A simple MLP (`Linear → activation → … → Linear`) — the quickstart
//! model and the E1/E2 training workload.

use super::linear::{reduce_row_partials, PackedLinearShard, ShardPlan, TP_LOGICAL_PARTS};
use super::{Linear, Module, PackedLinear};
use crate::autograd::{Tape, Var};
use crate::rng::derive_seed;
use crate::rnum::{rgelu_tanh, rtanh};
use crate::tensor::{Tensor, WorkerPool};
use crate::{Error, Result};

/// Activation choice for [`Mlp`].
#[derive(Clone, Copy, Debug)]
pub enum Act {
    /// ReLU.
    Relu,
    /// GELU (tanh graph).
    Gelu,
    /// tanh.
    Tanh,
}

/// Multi-layer perceptron.
pub struct Mlp {
    /// The linear layers.
    pub layers: Vec<Linear>,
    /// Activation between layers.
    pub act: Act,
}

impl Mlp {
    /// Build from layer widths, e.g. `[784, 256, 10]`.
    pub fn new(widths: &[usize], act: Act, seed: u64) -> Self {
        let layers = widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| Linear::new(w[0], w[1], derive_seed(seed, i as u64)))
            .collect();
        Mlp { layers, act }
    }

    /// Input feature count (first layer's `in_features`). Errors on a
    /// layer-less MLP (serving-facing: error, never panic).
    pub fn d_in(&self) -> Result<usize> {
        self.layers
            .first()
            .map(|l| l.weight.dims()[1])
            .ok_or_else(|| Error::config("mlp: no layers"))
    }

    /// Output feature count (last layer's `out_features`).
    pub fn d_out(&self) -> Result<usize> {
        self.layers
            .last()
            .map(|l| l.weight.dims()[0])
            .ok_or_else(|| Error::config("mlp: no layers"))
    }

    /// Off-tape inference forward on an explicit pool: the same
    /// `Linear → activation → … → Linear` graph as [`Module::forward`],
    /// with pooled GEMMs and elementwise activation maps instead of tape
    /// nodes. Each output row is an independent fixed-order reduction,
    /// so the pass is batch- and pool-size-invariant, and bits match the
    /// tape forward exactly (asserted in tests).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        self.forward_infer_packed_in(pool, x, None)
    }

    /// Freeze every layer's weights into microkernel panels
    /// (layout-only; see [`PackedLinear`]).
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedMlp> {
        Ok(PackedMlp {
            layers: self.layers.iter().map(|l| l.pack_in(pool)).collect::<Result<Vec<_>>>()?,
        })
    }

    /// [`Self::forward_infer_in`] parameterized over the GEMM route —
    /// one orchestration implementation so the packed and unpacked
    /// paths cannot drift (packing is bit-neutral; asserted in tests).
    pub fn forward_infer_packed_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        packed: Option<&PackedMlp>,
    ) -> Result<Tensor> {
        if let Some(p) = packed {
            if p.layers.len() != self.layers.len() {
                return Err(Error::shape("mlp: packed layer count mismatch"));
            }
        }
        let mut h = x.clone();
        for (i, l) in self.layers.iter().enumerate() {
            h = match packed {
                Some(p) => p.layers[i].forward_infer_in(pool, &h)?,
                None => l.forward_infer_in(pool, &h)?,
            };
            if i + 1 < self.layers.len() {
                // same elementwise graphs as Tape::{relu,gelu,tanh}
                h = match self.act {
                    Act::Relu => h.map(|t| if t > 0.0 { t } else { 0.0 }),
                    Act::Gelu => h.map(rgelu_tanh),
                    Act::Tanh => h.map(rtanh),
                };
            }
        }
        Ok(h)
    }
}

/// An [`Mlp`] with every layer frozen into microkernel panels; built by
/// [`Mlp::pack_in`].
pub struct PackedMlp {
    /// Packed layers, in order.
    pub layers: Vec<PackedLinear>,
}

impl Mlp {
    /// Freeze one tensor-parallel shard of this MLP under the Megatron
    /// plan: even layer indices are **column-split** (replicated input →
    /// this shard's output-column slice, bias and activation applied
    /// locally — element-wise, so layout-only), odd indices are
    /// **row-split** (each shard consumes its own column slice with zero
    /// communication and emits logical partials for the fixed tree).
    /// Indivisible widths are construction errors, never panics.
    pub fn pack_shard_in(&self, pool: &WorkerPool, plan: ShardPlan) -> Result<PackedMlpShard> {
        if self.layers.is_empty() {
            return Err(Error::config("mlp: no layers"));
        }
        let layers = self
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if i % 2 == 0 {
                    l.pack_col_shard_in(pool, plan)
                } else {
                    l.pack_row_shard_in(pool, plan)
                }
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PackedMlpShard { layers, plan })
    }

    /// Tensor-parallel inference forward: orchestrates one complete,
    /// in-order shard set (`shards[s]` built with
    /// `ShardPlan { tp: shards.len(), shard: s }`). Column-split layers
    /// run per shard on the replicated activation; row-split layers
    /// consume each shard's local slice and their logical partials
    /// combine in shard-index (= logical segment) order through the
    /// fixed tree + one bias add ([`reduce_row_partials`]). Bits are a
    /// pure function of the model and input — identical for every tp
    /// dividing [`TP_LOGICAL_PARTS`] (asserted in tests and
    /// `tests/tp_invariance.rs`).
    pub fn forward_infer_sharded_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        shards: &[PackedMlpShard],
    ) -> Result<Tensor> {
        let tp = shards.len();
        if tp == 0 {
            return Err(Error::shape("mlp: empty shard set"));
        }
        for (s, sh) in shards.iter().enumerate() {
            if sh.plan.tp != tp || sh.plan.shard != s || sh.layers.len() != self.layers.len() {
                return Err(Error::shape(
                    "mlp: shard set does not match this model's shard plan",
                ));
            }
        }
        // `full` = replicated activation (input, or a row layer's
        // reduced output); `locals` = per-shard column slices after a
        // col layer. Parity alternates, so exactly one is live.
        let mut full: Option<Tensor> = Some(x.clone());
        let mut locals: Vec<Tensor> = Vec::new();
        for i in 0..self.layers.len() {
            if i % 2 == 0 {
                let xin = full
                    .take()
                    .ok_or_else(|| Error::runtime("mlp: missing replicated activation"))?;
                locals = shards
                    .iter()
                    .map(|sh| sh.layers[i].forward_col_in(pool, &xin))
                    .collect::<Result<Vec<_>>>()?;
            } else {
                let mut parts = Vec::with_capacity(TP_LOGICAL_PARTS);
                for (s, sh) in shards.iter().enumerate() {
                    parts.extend(sh.layers[i].forward_row_partials_in(pool, &locals[s], true)?);
                }
                full = Some(reduce_row_partials(&parts, &self.layers[i].bias)?);
                locals.clear();
            }
            if i + 1 < self.layers.len() {
                // element-wise activation — applied wherever the data
                // lives (local slices or the replicated tensor), which
                // is layout-only
                let f = |h: Tensor| match self.act {
                    Act::Relu => h.map(|t| if t > 0.0 { t } else { 0.0 }),
                    Act::Gelu => h.map(rgelu_tanh),
                    Act::Tanh => h.map(rtanh),
                };
                if i % 2 == 0 {
                    locals = locals.into_iter().map(f).collect();
                } else {
                    full = full.map(f);
                }
            }
        }
        if (self.layers.len() - 1) % 2 == 0 {
            // ended on a column split: concatenate shard slices in
            // fixed shard order (layout-only)
            let m = locals[0].dims()[0];
            let n: usize = locals.iter().map(|l| l.dims()[1]).sum();
            let mut y = Tensor::zeros(&[m, n]);
            let mut off = 0;
            for l in &locals {
                let w = l.dims()[1];
                for r in 0..m {
                    y.data_mut()[r * n + off..r * n + off + w]
                        .copy_from_slice(&l.data()[r * w..(r + 1) * w]);
                }
                off += w;
            }
            Ok(y)
        } else {
            full.ok_or_else(|| Error::runtime("mlp: missing reduced output"))
        }
    }
}

/// One tensor-parallel shard of an [`Mlp`] under the Megatron
/// even-column / odd-row plan; built by [`Mlp::pack_shard_in`].
pub struct PackedMlpShard {
    layers: Vec<PackedLinearShard>,
    plan: ShardPlan,
}

impl Module for Mlp {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let mut h = x;
        for (i, l) in self.layers.iter().enumerate() {
            h = l.forward(t, h, binds)?;
            if i + 1 < self.layers.len() {
                h = match self.act {
                    Act::Relu => t.relu(h),
                    Act::Gelu => t.gelu(h),
                    Act::Tanh => t.tanh(h),
                };
            }
        }
        Ok(h)
    }

    fn params(&self) -> Vec<&Tensor> {
        self.layers.iter().flat_map(|l| l.params()).collect()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        self.layers.iter_mut().flat_map(|l| l.params_mut()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_runs() {
        let m = Mlp::new(&[8, 16, 4], Act::Relu, 3);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.num_params(), 8 * 16 + 16 + 16 * 4 + 4);
        let x = Tensor::full(&[2, 8], 0.3);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut b = Vec::new();
        let y = m.forward(&mut t, xv, &mut b).unwrap();
        assert_eq!(t.value_ref(y).dims(), &[2, 4]);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise_for_every_activation() {
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32 * 0.29).sin()).collect())
            .unwrap();
        for act in [Act::Relu, Act::Gelu, Act::Tanh] {
            let m = Mlp::new(&[8, 16, 16, 4], act, 11);
            assert_eq!((m.d_in().unwrap(), m.d_out().unwrap()), (8, 4));
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let want = t.value(m.forward(&mut t, xv, &mut b).unwrap());
            for lanes in [1usize, 2, 4] {
                let pool = WorkerPool::new(lanes);
                let got = m.forward_infer_in(&pool, &x).unwrap();
                assert!(
                    got.bit_eq(&want),
                    "act={act:?} lanes={lanes}: off-tape MLP changed bits"
                );
            }
        }
    }

    #[test]
    fn packed_forward_matches_unpacked_bitwise() {
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32 * 0.29).sin()).collect())
            .unwrap();
        for act in [Act::Relu, Act::Gelu, Act::Tanh] {
            let m = Mlp::new(&[8, 16, 16, 4], act, 11);
            for lanes in [1usize, 4] {
                let pool = WorkerPool::new(lanes);
                let packed = m.pack_in(&pool).unwrap();
                let want = m.forward_infer_in(&pool, &x).unwrap();
                let got = m.forward_infer_packed_in(&pool, &x, Some(&packed)).unwrap();
                assert!(got.bit_eq(&want), "act={act:?} lanes={lanes}: packed MLP changed bits");
            }
        }
    }

    #[test]
    fn sharded_forward_is_tp_invariant() {
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32 * 0.29).sin()).collect())
            .unwrap();
        // odd layer count ends on a column split (exercises the concat),
        // even layer count ends on a row split (exercises the tree)
        for widths in [&[8usize, 12, 16, 4][..], &[8usize, 16, 4][..]] {
            for act in [Act::Relu, Act::Gelu, Act::Tanh] {
                let m = Mlp::new(widths, act, 11);
                let mut want: Option<Tensor> = None;
                for tp in [1usize, 2, 4] {
                    for lanes in [1usize, 2] {
                        let pool = WorkerPool::new(lanes);
                        let shards: Vec<_> = (0..tp)
                            .map(|s| m.pack_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap())
                            .collect();
                        let got = m.forward_infer_sharded_in(&pool, &x, &shards).unwrap();
                        assert_eq!(got.dims(), &[3, *widths.last().unwrap()]);
                        match &want {
                            None => want = Some(got),
                            Some(w) => assert!(
                                got.bit_eq(w),
                                "widths={widths:?} act={act:?} tp={tp} lanes={lanes}: sharded MLP changed bits"
                            ),
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_mlp_errors_never_panic() {
        let pool = WorkerPool::new(1);
        // row layer's in_features not divisible by the logical partial
        // count → construction error at every tp
        let bad = Mlp::new(&[8, 10, 6], Act::Relu, 3);
        for tp in [1usize, 2, 4] {
            assert!(bad.pack_shard_in(&pool, ShardPlan::new(tp, 0).unwrap()).is_err());
        }
        // col layer's out_features not divisible by tp
        let m = Mlp::new(&[8, 10, 4], Act::Relu, 3);
        assert!(m.pack_shard_in(&pool, ShardPlan::new(4, 0).unwrap()).is_err(), "10 % 4");
        // incomplete / out-of-order shard sets rejected at forward
        let m = Mlp::new(&[8, 16, 4], Act::Relu, 3);
        let s0 = m.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        let s1 = m.pack_shard_in(&pool, ShardPlan::new(2, 1).unwrap()).unwrap();
        let x = Tensor::zeros(&[2, 8]);
        assert!(m.forward_infer_sharded_in(&pool, &x, &[s1, s0]).is_err(), "order");
        let s0 = m.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        assert!(m.forward_infer_sharded_in(&pool, &x, &[s0]).is_err(), "incomplete");
        assert!(m.forward_infer_sharded_in(&pool, &x, &[]).is_err(), "empty");
    }

    #[test]
    fn training_step_reduces_loss() {
        let mut m = Mlp::new(&[4, 16, 2], Act::Tanh, 5);
        let x = Tensor::from_vec(&[4, 4], (0..16).map(|i| (i as f32 * 0.31).sin()).collect())
            .unwrap();
        let targets = vec![0usize, 1, 0, 1];
        let mut last = f32::INFINITY;
        for _ in 0..30 {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut binds = Vec::new();
            let logits = m.forward(&mut t, xv, &mut binds).unwrap();
            let loss = t.softmax_cross_entropy(logits, &targets).unwrap();
            t.backward(loss).unwrap();
            // plain SGD
            for (p, v) in m.params_mut().into_iter().zip(binds.iter()) {
                let g = t.grad(*v).unwrap();
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data().iter()) {
                    *pv -= 0.5 * gv;
                }
            }
            last = t.value(loss).data()[0];
        }
        assert!(last < 0.2, "loss did not drop: {last}");
    }
}
