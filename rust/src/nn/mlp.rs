//! A simple MLP (`Linear → activation → … → Linear`) — the quickstart
//! model and the E1/E2 training workload.

use super::{Linear, Module, PackedLinear};
use crate::autograd::{Tape, Var};
use crate::rng::derive_seed;
use crate::rnum::{rgelu_tanh, rtanh};
use crate::tensor::{Tensor, WorkerPool};
use crate::{Error, Result};

/// Activation choice for [`Mlp`].
#[derive(Clone, Copy, Debug)]
pub enum Act {
    /// ReLU.
    Relu,
    /// GELU (tanh graph).
    Gelu,
    /// tanh.
    Tanh,
}

/// Multi-layer perceptron.
pub struct Mlp {
    /// The linear layers.
    pub layers: Vec<Linear>,
    /// Activation between layers.
    pub act: Act,
}

impl Mlp {
    /// Build from layer widths, e.g. `[784, 256, 10]`.
    pub fn new(widths: &[usize], act: Act, seed: u64) -> Self {
        let layers = widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| Linear::new(w[0], w[1], derive_seed(seed, i as u64)))
            .collect();
        Mlp { layers, act }
    }

    /// Input feature count (first layer's `in_features`). Errors on a
    /// layer-less MLP (serving-facing: error, never panic).
    pub fn d_in(&self) -> Result<usize> {
        self.layers
            .first()
            .map(|l| l.weight.dims()[1])
            .ok_or_else(|| Error::config("mlp: no layers"))
    }

    /// Output feature count (last layer's `out_features`).
    pub fn d_out(&self) -> Result<usize> {
        self.layers
            .last()
            .map(|l| l.weight.dims()[0])
            .ok_or_else(|| Error::config("mlp: no layers"))
    }

    /// Off-tape inference forward on an explicit pool: the same
    /// `Linear → activation → … → Linear` graph as [`Module::forward`],
    /// with pooled GEMMs and elementwise activation maps instead of tape
    /// nodes. Each output row is an independent fixed-order reduction,
    /// so the pass is batch- and pool-size-invariant, and bits match the
    /// tape forward exactly (asserted in tests).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        self.forward_infer_packed_in(pool, x, None)
    }

    /// Freeze every layer's weights into microkernel panels
    /// (layout-only; see [`PackedLinear`]).
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedMlp> {
        Ok(PackedMlp {
            layers: self.layers.iter().map(|l| l.pack_in(pool)).collect::<Result<Vec<_>>>()?,
        })
    }

    /// [`Self::forward_infer_in`] parameterized over the GEMM route —
    /// one orchestration implementation so the packed and unpacked
    /// paths cannot drift (packing is bit-neutral; asserted in tests).
    pub fn forward_infer_packed_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        packed: Option<&PackedMlp>,
    ) -> Result<Tensor> {
        if let Some(p) = packed {
            if p.layers.len() != self.layers.len() {
                return Err(Error::shape("mlp: packed layer count mismatch"));
            }
        }
        let mut h = x.clone();
        for (i, l) in self.layers.iter().enumerate() {
            h = match packed {
                Some(p) => p.layers[i].forward_infer_in(pool, &h)?,
                None => l.forward_infer_in(pool, &h)?,
            };
            if i + 1 < self.layers.len() {
                // same elementwise graphs as Tape::{relu,gelu,tanh}
                h = match self.act {
                    Act::Relu => h.map(|t| if t > 0.0 { t } else { 0.0 }),
                    Act::Gelu => h.map(rgelu_tanh),
                    Act::Tanh => h.map(rtanh),
                };
            }
        }
        Ok(h)
    }
}

/// An [`Mlp`] with every layer frozen into microkernel panels; built by
/// [`Mlp::pack_in`].
pub struct PackedMlp {
    /// Packed layers, in order.
    pub layers: Vec<PackedLinear>,
}

impl Module for Mlp {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let mut h = x;
        for (i, l) in self.layers.iter().enumerate() {
            h = l.forward(t, h, binds)?;
            if i + 1 < self.layers.len() {
                h = match self.act {
                    Act::Relu => t.relu(h),
                    Act::Gelu => t.gelu(h),
                    Act::Tanh => t.tanh(h),
                };
            }
        }
        Ok(h)
    }

    fn params(&self) -> Vec<&Tensor> {
        self.layers.iter().flat_map(|l| l.params()).collect()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        self.layers.iter_mut().flat_map(|l| l.params_mut()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_runs() {
        let m = Mlp::new(&[8, 16, 4], Act::Relu, 3);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.num_params(), 8 * 16 + 16 + 16 * 4 + 4);
        let x = Tensor::full(&[2, 8], 0.3);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut b = Vec::new();
        let y = m.forward(&mut t, xv, &mut b).unwrap();
        assert_eq!(t.value_ref(y).dims(), &[2, 4]);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise_for_every_activation() {
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32 * 0.29).sin()).collect())
            .unwrap();
        for act in [Act::Relu, Act::Gelu, Act::Tanh] {
            let m = Mlp::new(&[8, 16, 16, 4], act, 11);
            assert_eq!((m.d_in().unwrap(), m.d_out().unwrap()), (8, 4));
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let want = t.value(m.forward(&mut t, xv, &mut b).unwrap());
            for lanes in [1usize, 2, 4] {
                let pool = WorkerPool::new(lanes);
                let got = m.forward_infer_in(&pool, &x).unwrap();
                assert!(
                    got.bit_eq(&want),
                    "act={act:?} lanes={lanes}: off-tape MLP changed bits"
                );
            }
        }
    }

    #[test]
    fn packed_forward_matches_unpacked_bitwise() {
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32 * 0.29).sin()).collect())
            .unwrap();
        for act in [Act::Relu, Act::Gelu, Act::Tanh] {
            let m = Mlp::new(&[8, 16, 16, 4], act, 11);
            for lanes in [1usize, 4] {
                let pool = WorkerPool::new(lanes);
                let packed = m.pack_in(&pool).unwrap();
                let want = m.forward_infer_in(&pool, &x).unwrap();
                let got = m.forward_infer_packed_in(&pool, &x, Some(&packed)).unwrap();
                assert!(got.bit_eq(&want), "act={act:?} lanes={lanes}: packed MLP changed bits");
            }
        }
    }

    #[test]
    fn training_step_reduces_loss() {
        let mut m = Mlp::new(&[4, 16, 2], Act::Tanh, 5);
        let x = Tensor::from_vec(&[4, 4], (0..16).map(|i| (i as f32 * 0.31).sin()).collect())
            .unwrap();
        let targets = vec![0usize, 1, 0, 1];
        let mut last = f32::INFINITY;
        for _ in 0..30 {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut binds = Vec::new();
            let logits = m.forward(&mut t, xv, &mut binds).unwrap();
            let loss = t.softmax_cross_entropy(logits, &targets).unwrap();
            t.backward(loss).unwrap();
            // plain SGD
            for (p, v) in m.params_mut().into_iter().zip(binds.iter()) {
                let g = t.grad(*v).unwrap();
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data().iter()) {
                    *pv -= 0.5 * gv;
                }
            }
            last = t.value(loss).data()[0];
        }
        assert!(last < 0.2, "loss did not drop: {last}");
    }
}
