//! PyTorch-compatible neural-network modules (paper §3, "APIs inherited
//! from PyTorch ... keeping their names and parameter definitions
//! intact"): `Linear`, `Conv2d`, `BatchNorm2d`, `LayerNorm`, `Embedding`,
//! `MultiheadAttention`, activations, losses — every one a **fixed
//! computation graph** over the reproducible `tensor`/`rnum` kernels.
//!
//! Binding contract: a module registers its parameters on the tape in the
//! same fixed order that [`Module::params`] / [`Module::params_mut`]
//! enumerate them, appending the tape `Var`s to the `binds` list. The
//! trainer relies on this order to route gradients back — one more fixed
//! order in the spirit of the paper.

pub mod activation;
pub mod attention;
pub mod batchnorm;
pub mod conv2d;
pub mod embedding;
pub mod layernorm;
pub mod linear;
pub mod mlp;
pub mod softmax;
pub mod transformer;

pub use attention::{
    attention_forward, attention_step_forward, KvState, MultiheadAttention, PackedAttention,
    PackedAttentionShard,
};
pub use batchnorm::{batch_norm, batch_norm_affine_folded, batch_norm_folded, BatchNorm2d};
pub use conv2d::Conv2d;
pub use embedding::Embedding;
pub use layernorm::{layer_norm_forward, LayerNorm};
pub use linear::{
    reduce_row_partials, Linear, PackedLinear, PackedLinearShard, ShardPlan, TP_LOGICAL_PARTS,
};
pub use mlp::{Act, Mlp, PackedMlp, PackedMlpShard};
pub use softmax::{log_softmax_rows, softmax_rows};
pub use transformer::{
    CharTransformer, PackedBlock, PackedBlockShard, PackedTransformer, PackedTransformerShard,
    TransformerBlock, TransformerConfig, TransformerKv,
};

use crate::autograd::{Tape, Var};
use crate::tensor::Tensor;
use crate::Result;

/// A layer with tape-forward and enumerable parameters.
pub trait Module {
    /// Forward pass; must register parameters in `params()` order.
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var>;
    /// Parameters in fixed order.
    fn params(&self) -> Vec<&Tensor>;
    /// Mutable parameters in the same fixed order.
    fn params_mut(&mut self) -> Vec<&mut Tensor>;
    /// Total parameter count.
    fn num_params(&self) -> usize {
        self.params().iter().map(|p| p.numel()).sum()
    }
}
