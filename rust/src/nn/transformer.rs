//! A small GPT-style character transformer — the end-to-end training
//! workload (experiment E8). Pre-norm blocks, causal attention, GELU MLP,
//! learned positional embeddings; every sub-op is a RepDL fixed graph.

use super::attention::PackedAttentionShard;
use super::linear::{reduce_row_partials, PackedLinearShard, ShardPlan, TP_LOGICAL_PARTS};
use super::{
    Embedding, KvState, LayerNorm, Linear, Module, MultiheadAttention, PackedAttention,
    PackedLinear,
};
use crate::autograd::{Tape, Var};
use crate::rng::derive_seed;
use crate::rnum::rgelu_tanh;
use crate::tensor::{Tensor, WorkerPool};
use crate::{Error, Result};

/// Transformer hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TransformerConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub dim: usize,
    /// Head count.
    pub heads: usize,
    /// Block count.
    pub layers: usize,
    /// Context length.
    pub context: usize,
    /// MLP expansion factor.
    pub mlp_ratio: usize,
}

impl Default for TransformerConfig {
    fn default() -> Self {
        TransformerConfig { vocab: 64, dim: 64, heads: 4, layers: 2, context: 32, mlp_ratio: 4 }
    }
}

/// Pre-norm transformer block.
pub struct TransformerBlock {
    /// First LayerNorm.
    pub ln1: LayerNorm,
    /// Attention.
    pub attn: MultiheadAttention,
    /// Second LayerNorm.
    pub ln2: LayerNorm,
    /// MLP up-projection.
    pub fc1: Linear,
    /// MLP down-projection.
    pub fc2: Linear,
}

impl TransformerBlock {
    /// New block.
    pub fn new(dim: usize, heads: usize, mlp_ratio: usize, seed: u64) -> Result<Self> {
        if mlp_ratio == 0 {
            // a zero-width fc1 is as degenerate as dim/heads = 0 — reject
            // at construction like the rest (serving-facing: error, not
            // a downstream GEMM panic)
            return Err(Error::shape("TransformerBlock: zero mlp_ratio"));
        }
        Ok(TransformerBlock {
            ln1: LayerNorm::new(dim),
            attn: MultiheadAttention::new(dim, heads, true, derive_seed(seed, 0))?,
            ln2: LayerNorm::new(dim),
            fc1: Linear::new(dim, dim * mlp_ratio, derive_seed(seed, 1)),
            fc2: Linear::new(dim * mlp_ratio, dim, derive_seed(seed, 2)),
        })
    }
}

impl TransformerBlock {
    /// Off-tape inference forward on a (T, D) sequence: the same
    /// pre-norm graph as [`Module::forward`] — LN → attention →
    /// residual, LN → GELU MLP → residual — through the off-tape layer
    /// forwards ([`LayerNorm::forward_infer`],
    /// [`MultiheadAttention::forward_seq_infer_in`],
    /// [`Linear::forward_infer_in`]) with no tape node allocation.
    /// Bit-identical to the tape forward (asserted in tests).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        self.forward_infer_packed_in(pool, x, None, None)
    }

    /// Freeze the block's three GEMM layers into microkernel panels
    /// (layout-only; see [`PackedLinear`]).
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedBlock> {
        Ok(PackedBlock {
            attn: self.attn.pack_in(pool)?,
            fc1: self.fc1.pack_in(pool)?,
            fc2: self.fc2.pack_in(pool)?,
        })
    }

    /// [`Self::forward_infer_in`] parameterized over the GEMM route and
    /// an optional per-layer KV capture — one orchestration
    /// implementation, so packed/unpacked/capturing paths cannot drift.
    pub fn forward_infer_packed_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        packed: Option<&PackedBlock>,
        kv_out: Option<&mut KvState>,
    ) -> Result<Tensor> {
        let h = self.ln1.forward_infer(x)?;
        let h = self.attn.forward_seq_packed_in(pool, &h, packed.map(|p| &p.attn), kv_out)?;
        let x = x.add_t(&h)?; // residual
        let h = self.ln2.forward_infer(&x)?;
        let h = match packed {
            Some(p) => p.fc1.forward_infer_in(pool, &h)?,
            None => self.fc1.forward_infer_in(pool, &h)?,
        };
        let h = h.map(rgelu_tanh); // same elementwise graph as Tape::gelu
        let h = match packed {
            Some(p) => p.fc2.forward_infer_in(pool, &h)?,
            None => self.fc2.forward_infer_in(pool, &h)?,
        };
        x.add_t(&h) // residual
    }

    /// Incremental decode through the block: one (1, D) position against
    /// the layer's KV cache. Every sub-op (LN row, GEMM row, gelu map,
    /// residual add, attention row) is row-independent with an identical
    /// per-row graph, so this equals the last row of
    /// [`Self::forward_infer_in`] over the full prefix, bit for bit.
    pub fn forward_step_infer_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        kv: &mut KvState,
    ) -> Result<Tensor> {
        self.forward_step_packed_in(pool, x, kv, None)
    }

    /// [`Self::forward_step_infer_in`] parameterized over the GEMM route.
    pub fn forward_step_packed_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        kv: &mut KvState,
        packed: Option<&PackedBlock>,
    ) -> Result<Tensor> {
        let h = self.ln1.forward_infer(x)?;
        let h = self.attn.forward_step_packed_in(pool, &h, kv, packed.map(|p| &p.attn))?;
        let x = x.add_t(&h)?; // residual
        let h = self.ln2.forward_infer(&x)?;
        let h = match packed {
            Some(p) => p.fc1.forward_infer_in(pool, &h)?,
            None => self.fc1.forward_infer_in(pool, &h)?,
        };
        let h = h.map(rgelu_tanh);
        let h = match packed {
            Some(p) => p.fc2.forward_infer_in(pool, &h)?,
            None => self.fc2.forward_infer_in(pool, &h)?,
        };
        x.add_t(&h) // residual
    }
}

impl TransformerBlock {
    /// Freeze one tensor-parallel shard of this block: per-head
    /// attention sharding ([`MultiheadAttention::pack_shard_in`]), plus
    /// the Megatron MLP plan — fc1 column-split (bias + GELU applied
    /// locally, element-wise so layout-only), fc2 row-split consuming
    /// the shard's own fc1 slice with zero communication. Indivisible
    /// head/width counts are errors, never panics.
    pub fn pack_shard_in(&self, pool: &WorkerPool, plan: ShardPlan) -> Result<PackedBlockShard> {
        Ok(PackedBlockShard {
            attn: self.attn.pack_shard_in(pool, plan)?,
            fc1: self.fc1.pack_col_shard_in(pool, plan)?,
            fc2: self.fc2.pack_row_shard_in(pool, plan)?,
        })
    }

    /// Tensor-parallel forward on a (T, D) sequence: LayerNorms and
    /// residual adds run replicated (element-wise per row — layout
    /// identical at any tp), attention shards by head, and the MLP runs
    /// the Megatron column→row plan with the fixed-tree partial
    /// reduction. Bits are TP-invariant (asserted in tests and
    /// `tests/tp_invariance.rs`).
    pub fn forward_infer_sharded_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        shards: &[&PackedBlockShard],
        kv_out: Option<&mut KvState>,
    ) -> Result<Tensor> {
        let h = self.ln1.forward_infer(x)?;
        let attn_shards: Vec<&PackedAttentionShard> = shards.iter().map(|b| &b.attn).collect();
        let h = self.attn.forward_seq_sharded_in(pool, &h, &attn_shards, kv_out)?;
        let x = x.add_t(&h)?; // residual
        let h = self.ln2.forward_infer(&x)?;
        let mut parts = Vec::with_capacity(TP_LOGICAL_PARTS);
        for b in shards {
            let local = b.fc1.forward_col_in(pool, &h)?;
            let local = local.map(rgelu_tanh); // element-wise, shard-local
            parts.extend(b.fc2.forward_row_partials_in(pool, &local, true)?);
        }
        let h = reduce_row_partials(&parts, &self.fc2.bias)?;
        x.add_t(&h) // residual
    }

    /// Tensor-parallel incremental decode through the block — the
    /// sharded analogue of [`Self::forward_step_packed_in`], against the
    /// same full-layout KV cache every TP width shares.
    pub fn forward_step_sharded_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        shards: &[&PackedBlockShard],
        kv: &mut KvState,
    ) -> Result<Tensor> {
        let h = self.ln1.forward_infer(x)?;
        let attn_shards: Vec<&PackedAttentionShard> = shards.iter().map(|b| &b.attn).collect();
        let h = self.attn.forward_step_sharded_in(pool, &h, &attn_shards, kv)?;
        let x = x.add_t(&h)?; // residual
        let h = self.ln2.forward_infer(&x)?;
        let mut parts = Vec::with_capacity(TP_LOGICAL_PARTS);
        for b in shards {
            let local = b.fc1.forward_col_in(pool, &h)?;
            let local = local.map(rgelu_tanh);
            parts.extend(b.fc2.forward_row_partials_in(pool, &local, true)?);
        }
        let h = reduce_row_partials(&parts, &self.fc2.bias)?;
        x.add_t(&h) // residual
    }
}

/// A [`TransformerBlock`] with all GEMM layers frozen into microkernel
/// panels; built by [`TransformerBlock::pack_in`].
pub struct PackedBlock {
    /// Packed attention projections.
    pub attn: PackedAttention,
    /// Packed MLP up-projection.
    pub fc1: PackedLinear,
    /// Packed MLP down-projection.
    pub fc2: PackedLinear,
}

impl Module for TransformerBlock {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let h = self.ln1.forward(t, x, binds)?;
        let h = self.attn.forward_seq(t, h, binds)?;
        let x = t.add(x, h)?; // residual
        let h = self.ln2.forward(t, x, binds)?;
        let h = self.fc1.forward(t, h, binds)?;
        let h = t.gelu(h);
        let h = self.fc2.forward(t, h, binds)?;
        t.add(x, h) // residual
    }

    fn params(&self) -> Vec<&Tensor> {
        let mut p = self.ln1.params();
        p.extend(self.attn.params());
        p.extend(self.ln2.params());
        p.extend(self.fc1.params());
        p.extend(self.fc2.params());
        p
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.ln1.params_mut();
        p.extend(self.attn.params_mut());
        p.extend(self.ln2.params_mut());
        p.extend(self.fc1.params_mut());
        p.extend(self.fc2.params_mut());
        p
    }
}

/// GPT-style char LM.
pub struct CharTransformer {
    /// Config.
    pub cfg: TransformerConfig,
    /// Token embedding.
    pub tok_emb: Embedding,
    /// Positional embedding (context, dim) as a raw parameter.
    pub pos_emb: Tensor,
    /// Blocks.
    pub blocks: Vec<TransformerBlock>,
    /// Final LayerNorm.
    pub ln_f: LayerNorm,
    /// LM head (vocab logits).
    pub head: Linear,
}

impl CharTransformer {
    /// Build with reproducible init.
    pub fn new(cfg: TransformerConfig, seed: u64) -> Result<Self> {
        let blocks = (0..cfg.layers)
            .map(|i| TransformerBlock::new(cfg.dim, cfg.heads, cfg.mlp_ratio, derive_seed(seed, 10 + i as u64)))
            .collect::<Result<Vec<_>>>()?;
        Ok(CharTransformer {
            cfg,
            tok_emb: Embedding::new(cfg.vocab, cfg.dim, 0.02, derive_seed(seed, 0)),
            pos_emb: crate::rng::normal_tensor(&[cfg.context, cfg.dim], 0.0, 0.02, derive_seed(seed, 1)),
            blocks,
            ln_f: LayerNorm::new(cfg.dim),
            head: Linear::new(cfg.dim, cfg.vocab, derive_seed(seed, 2)),
        })
    }

    /// Forward one sequence of token ids (≤ context) to (T, vocab) logits.
    pub fn forward_logits(&self, t: &mut Tape, ids: &[usize], binds: &mut Vec<Var>) -> Result<Var> {
        let tt = ids.len();
        let e = self.tok_emb.forward(t, ids, binds)?; // (T, D)
        let pe = t.param(self.pos_emb.clone());
        binds.push(pe);
        let pe_t = t.slice_rows(pe, 0, tt)?;
        let mut h = t.add(e, pe_t)?;
        for b in &self.blocks {
            h = b.forward(t, h, binds)?;
        }
        let h = self.ln_f.forward(t, h, binds)?;
        self.head.forward(t, h, binds)
    }

    /// Next-token cross-entropy over one sequence:
    /// inputs ids[0..T−1], targets ids[1..T].
    pub fn loss_on_sequence(&self, t: &mut Tape, ids: &[usize], binds: &mut Vec<Var>) -> Result<Var> {
        let inputs = &ids[..ids.len() - 1];
        let targets = &ids[1..];
        let logits = self.forward_logits(t, inputs, binds)?;
        t.softmax_cross_entropy(logits, targets)
    }

    /// Off-tape inference forward on an explicit pool: one sequence of
    /// token ids (`0 < len ≤ context`) to (T, vocab) logits, with **no
    /// `Tape` allocation** — embedding lookup and the positional-row
    /// slice are plain row copies (layout-only), the blocks run
    /// [`TransformerBlock::forward_infer_in`], and the head is a pooled
    /// GEMM. Every op follows the identical fixed graph as
    /// [`Self::forward_logits`], so the logits are bit-identical to the
    /// tape forward (asserted in tests and pinned against the
    /// independent Python emulator in `tests/golden_vectors.rs`).
    /// Serving-facing: out-of-range ids and bad lengths are errors,
    /// never panics.
    pub fn forward_logits_infer_in(&self, pool: &WorkerPool, ids: &[usize]) -> Result<Tensor> {
        self.forward_logits_packed_in(pool, ids, None, None)
    }

    /// Fresh (empty) per-layer KV caches for incremental decoding.
    pub fn begin_kv(&self) -> TransformerKv {
        let dh = self.cfg.dim / self.cfg.heads.max(1);
        TransformerKv {
            layers: self.blocks.iter().map(|_| KvState::new(self.cfg.heads, dh)).collect(),
            steps: 0,
        }
    }

    /// Freeze every GEMM layer (all blocks + LM head) into microkernel
    /// panels (layout-only; see [`PackedLinear`]).
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedTransformer> {
        Ok(PackedTransformer {
            blocks: self.blocks.iter().map(|b| b.pack_in(pool)).collect::<Result<Vec<_>>>()?,
            head: self.head.pack_in(pool)?,
        })
    }

    /// [`Self::forward_logits_infer_in`] parameterized over the GEMM
    /// route and an optional KV prefill capture — one orchestration
    /// implementation for the packed/unpacked/capturing paths.
    ///
    /// `kv_out`, when given, must be fresh ([`Self::begin_kv`]); after
    /// the call it holds every layer's K/V rows for the whole sequence,
    /// captured as layout copies during this single O(T) forward — so a
    /// session rebuild after eviction costs one full forward, never a
    /// token-by-token O(T²) replay.
    pub fn forward_logits_packed_in(
        &self,
        pool: &WorkerPool,
        ids: &[usize],
        packed: Option<&PackedTransformer>,
        mut kv_out: Option<&mut TransformerKv>,
    ) -> Result<Tensor> {
        let tt = ids.len();
        if tt == 0 || tt > self.cfg.context {
            return Err(Error::shape(format!(
                "transformer infer: sequence length {tt} not in 1..={}",
                self.cfg.context
            )));
        }
        let dim = self.cfg.dim;
        let table = &self.tok_emb.weight;
        for &i in ids {
            if i >= self.cfg.vocab {
                return Err(Error::shape(format!(
                    "transformer infer: id {i} ≥ vocab {}",
                    self.cfg.vocab
                )));
            }
        }
        if let Some(p) = packed {
            if p.blocks.len() != self.blocks.len() {
                return Err(Error::shape("transformer infer: packed layer count mismatch"));
            }
        }
        if let Some(kvs) = kv_out.as_deref_mut() {
            if kvs.steps() != 0 || kvs.layers.len() != self.blocks.len() {
                return Err(Error::shape(
                    "transformer infer: kv_out must be a fresh begin_kv() cache",
                ));
            }
        }
        // token embedding + positional rows (both layout-only lookups)
        let mut e = Tensor::zeros(&[tt, dim]);
        for (r, &i) in ids.iter().enumerate() {
            e.data_mut()[r * dim..(r + 1) * dim]
                .copy_from_slice(&table.data()[i * dim..(i + 1) * dim]);
        }
        let mut pe = Tensor::zeros(&[tt, dim]);
        pe.data_mut().copy_from_slice(&self.pos_emb.data()[..tt * dim]);
        let mut h = e.add_t(&pe)?;
        for (li, b) in self.blocks.iter().enumerate() {
            let kv_l = kv_out.as_deref_mut().map(|k| &mut k.layers[li]);
            h = b.forward_infer_packed_in(pool, &h, packed.map(|p| &p.blocks[li]), kv_l)?;
        }
        if let Some(kvs) = kv_out.as_deref_mut() {
            kvs.steps = tt;
        }
        let h = self.ln_f.forward_infer(&h)?;
        match packed {
            Some(p) => p.head.forward_infer_in(pool, &h),
            None => self.head.forward_infer_in(pool, &h),
        }
    }

    /// Incremental decode: ONE new token id against the session's KV
    /// caches, returning the (1, vocab) logits row for that position —
    /// O(T) work instead of the O(T²) full recompute, bit-identical to
    /// the last row of [`Self::forward_logits_infer_in`] over the full
    /// prefix (asserted in tests and `tests/serve_sessions.rs`).
    pub fn forward_logits_step_infer_in(
        &self,
        pool: &WorkerPool,
        id: usize,
        kv: &mut TransformerKv,
    ) -> Result<Tensor> {
        self.forward_logits_step_packed_in(pool, id, kv, None)
    }

    /// [`Self::forward_logits_step_infer_in`] parameterized over the
    /// GEMM route.
    pub fn forward_logits_step_packed_in(
        &self,
        pool: &WorkerPool,
        id: usize,
        kv: &mut TransformerKv,
        packed: Option<&PackedTransformer>,
    ) -> Result<Tensor> {
        let pos = kv.steps;
        if pos >= self.cfg.context {
            return Err(Error::shape(format!(
                "transformer step: position {pos} ≥ context {}",
                self.cfg.context
            )));
        }
        if id >= self.cfg.vocab {
            return Err(Error::shape(format!(
                "transformer step: id {id} ≥ vocab {}",
                self.cfg.vocab
            )));
        }
        if kv.layers.len() != self.blocks.len() {
            return Err(Error::shape("transformer step: KV layer count mismatch"));
        }
        if let Some(p) = packed {
            if p.blocks.len() != self.blocks.len() {
                return Err(Error::shape("transformer step: packed layer count mismatch"));
            }
        }
        let dim = self.cfg.dim;
        // this token's embedding row + positional row `pos`
        let mut e = Tensor::zeros(&[1, dim]);
        e.data_mut()
            .copy_from_slice(&self.tok_emb.weight.data()[id * dim..(id + 1) * dim]);
        let mut pe = Tensor::zeros(&[1, dim]);
        pe.data_mut().copy_from_slice(&self.pos_emb.data()[pos * dim..(pos + 1) * dim]);
        let mut h = e.add_t(&pe)?;
        for (li, b) in self.blocks.iter().enumerate() {
            h = b.forward_step_packed_in(pool, &h, &mut kv.layers[li], packed.map(|p| &p.blocks[li]))?;
        }
        kv.steps = pos + 1;
        let h = self.ln_f.forward_infer(&h)?;
        match packed {
            Some(p) => p.head.forward_infer_in(pool, &h),
            None => self.head.forward_infer_in(pool, &h),
        }
    }

    /// Freeze one tensor-parallel shard of the whole model: every block
    /// via [`TransformerBlock::pack_shard_in`] plus the LM head as a
    /// row split over the replicated final activation (works for any
    /// vocab size — the head's *input* width is what must divide
    /// [`TP_LOGICAL_PARTS`]). Embeddings and LayerNorms carry no GEMM
    /// and stay with the unsharded model.
    pub fn pack_shard_in(&self, pool: &WorkerPool, plan: ShardPlan) -> Result<PackedTransformerShard> {
        Ok(PackedTransformerShard {
            blocks: self
                .blocks
                .iter()
                .map(|b| b.pack_shard_in(pool, plan))
                .collect::<Result<Vec<_>>>()?,
            head: self.head.pack_row_shard_in(pool, plan)?,
            plan,
        })
    }

    /// Validate a complete, in-order tensor-parallel shard set for this
    /// model.
    fn check_tp_shards(&self, shards: &[PackedTransformerShard]) -> Result<()> {
        let tp = shards.len();
        if tp == 0 {
            return Err(Error::shape("transformer: empty shard set"));
        }
        for (s, sh) in shards.iter().enumerate() {
            if sh.plan.tp != tp || sh.plan.shard != s || sh.blocks.len() != self.blocks.len() {
                return Err(Error::shape(
                    "transformer: shard set does not match this model's shard plan",
                ));
            }
        }
        Ok(())
    }

    /// Tensor-parallel logits forward — the sharded analogue of
    /// [`Self::forward_logits_packed_in`], with identical validation and
    /// the identical embedding/positional/LayerNorm graph (replicated).
    /// Blocks shard by head + Megatron MLP; the LM head's logical
    /// partials combine through the fixed tree. Bits, and any captured
    /// KV cache, are identical at every tp dividing
    /// [`TP_LOGICAL_PARTS`] (asserted in `tests/tp_invariance.rs`).
    pub fn forward_logits_sharded_in(
        &self,
        pool: &WorkerPool,
        ids: &[usize],
        shards: &[PackedTransformerShard],
        mut kv_out: Option<&mut TransformerKv>,
    ) -> Result<Tensor> {
        self.check_tp_shards(shards)?;
        let tt = ids.len();
        if tt == 0 || tt > self.cfg.context {
            return Err(Error::shape(format!(
                "transformer infer: sequence length {tt} not in 1..={}",
                self.cfg.context
            )));
        }
        let dim = self.cfg.dim;
        let table = &self.tok_emb.weight;
        for &i in ids {
            if i >= self.cfg.vocab {
                return Err(Error::shape(format!(
                    "transformer infer: id {i} ≥ vocab {}",
                    self.cfg.vocab
                )));
            }
        }
        if let Some(kvs) = kv_out.as_deref_mut() {
            if kvs.steps() != 0 || kvs.layers.len() != self.blocks.len() {
                return Err(Error::shape(
                    "transformer infer: kv_out must be a fresh begin_kv() cache",
                ));
            }
        }
        let mut e = Tensor::zeros(&[tt, dim]);
        for (r, &i) in ids.iter().enumerate() {
            e.data_mut()[r * dim..(r + 1) * dim]
                .copy_from_slice(&table.data()[i * dim..(i + 1) * dim]);
        }
        let mut pe = Tensor::zeros(&[tt, dim]);
        pe.data_mut().copy_from_slice(&self.pos_emb.data()[..tt * dim]);
        let mut h = e.add_t(&pe)?;
        for (li, b) in self.blocks.iter().enumerate() {
            let kv_l = kv_out.as_deref_mut().map(|k| &mut k.layers[li]);
            let block_shards: Vec<&PackedBlockShard> =
                shards.iter().map(|sh| &sh.blocks[li]).collect();
            h = b.forward_infer_sharded_in(pool, &h, &block_shards, kv_l)?;
        }
        if let Some(kvs) = kv_out.as_deref_mut() {
            kvs.steps = tt;
        }
        let h = self.ln_f.forward_infer(&h)?;
        let mut parts = Vec::with_capacity(TP_LOGICAL_PARTS);
        for sh in shards {
            parts.extend(sh.head.forward_row_partials_in(pool, &h, false)?);
        }
        reduce_row_partials(&parts, &self.head.bias)
    }

    /// Tensor-parallel incremental decode — the sharded analogue of
    /// [`Self::forward_logits_step_packed_in`] against the same
    /// full-layout session caches, so a session prefilled or stepped at
    /// one TP width continues bit-identically at another.
    pub fn forward_logits_step_sharded_in(
        &self,
        pool: &WorkerPool,
        id: usize,
        shards: &[PackedTransformerShard],
        kv: &mut TransformerKv,
    ) -> Result<Tensor> {
        self.check_tp_shards(shards)?;
        let pos = kv.steps;
        if pos >= self.cfg.context {
            return Err(Error::shape(format!(
                "transformer step: position {pos} ≥ context {}",
                self.cfg.context
            )));
        }
        if id >= self.cfg.vocab {
            return Err(Error::shape(format!(
                "transformer step: id {id} ≥ vocab {}",
                self.cfg.vocab
            )));
        }
        if kv.layers.len() != self.blocks.len() {
            return Err(Error::shape("transformer step: KV layer count mismatch"));
        }
        let dim = self.cfg.dim;
        let mut e = Tensor::zeros(&[1, dim]);
        e.data_mut()
            .copy_from_slice(&self.tok_emb.weight.data()[id * dim..(id + 1) * dim]);
        let mut pe = Tensor::zeros(&[1, dim]);
        pe.data_mut().copy_from_slice(&self.pos_emb.data()[pos * dim..(pos + 1) * dim]);
        let mut h = e.add_t(&pe)?;
        for (li, b) in self.blocks.iter().enumerate() {
            let block_shards: Vec<&PackedBlockShard> =
                shards.iter().map(|sh| &sh.blocks[li]).collect();
            h = b.forward_step_sharded_in(pool, &h, &block_shards, &mut kv.layers[li])?;
        }
        kv.steps = pos + 1;
        let h = self.ln_f.forward_infer(&h)?;
        let mut parts = Vec::with_capacity(TP_LOGICAL_PARTS);
        for sh in shards {
            parts.extend(sh.head.forward_row_partials_in(pool, &h, false)?);
        }
        reduce_row_partials(&parts, &self.head.bias)
    }

    /// All parameters in fixed traversal order (same order as
    /// [`Self::params_mut`] — the model-state fingerprint and the serve
    /// tower's `weights_hash` both rely on it).
    pub fn params(&self) -> Vec<&Tensor> {
        let mut p = self.tok_emb.params();
        p.push(&self.pos_emb);
        for b in &self.blocks {
            p.extend(b.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }

    /// All parameters in fixed traversal order (must match forward
    /// registration order — asserted in tests).
    pub fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.tok_emb.params_mut();
        p.push(&mut self.pos_emb);
        for b in &mut self.blocks {
            p.extend(b.params_mut());
        }
        p.extend(self.ln_f.params_mut());
        p.extend(self.head.params_mut());
        p
    }

    /// Parameter count.
    pub fn num_params(&self) -> usize {
        let mut n = self.tok_emb.weight.numel() + self.pos_emb.numel();
        for b in &self.blocks {
            n += b.num_params();
        }
        n += self.ln_f.num_params() + self.head.num_params();
        n
    }
}

/// Per-session decoding state: one [`KvState`] per block plus the
/// number of positions decoded so far (= the next position index).
/// Cloneable — the serve-side session store hands out copies so a
/// stored session is never mutated in place.
#[derive(Clone)]
pub struct TransformerKv {
    /// Per-layer attention caches, in block order.
    pub layers: Vec<KvState>,
    steps: usize,
}

impl TransformerKv {
    /// Number of positions decoded into this cache.
    pub fn steps(&self) -> usize {
        self.steps
    }
}

/// A [`CharTransformer`] with every GEMM layer frozen into microkernel
/// panels; built by [`CharTransformer::pack_in`]. The embeddings and
/// LayerNorms carry no GEMM and are read from the source model.
pub struct PackedTransformer {
    /// Packed blocks, in block order.
    pub blocks: Vec<PackedBlock>,
    /// Packed LM head.
    pub head: PackedLinear,
}

/// One tensor-parallel shard of a [`TransformerBlock`]: per-head
/// attention shard plus the Megatron column/row MLP pair. Built by
/// [`TransformerBlock::pack_shard_in`].
pub struct PackedBlockShard {
    attn: PackedAttentionShard,
    fc1: PackedLinearShard,
    fc2: PackedLinearShard,
}

/// One tensor-parallel shard of a [`CharTransformer`] — every block's
/// shard plus the row-split LM head, tagged with its [`ShardPlan`].
/// Built by [`CharTransformer::pack_shard_in`]; a complete in-order set
/// of these drives [`CharTransformer::forward_logits_sharded_in`].
pub struct PackedTransformerShard {
    blocks: Vec<PackedBlockShard>,
    head: PackedLinearShard,
    plan: ShardPlan,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_counts_params() {
        let cfg = TransformerConfig { vocab: 20, dim: 16, heads: 2, layers: 2, context: 8, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 1).unwrap();
        assert!(m.num_params() > 4000, "n={}", m.num_params());
        // init reproducible
        let m2 = CharTransformer::new(cfg, 1).unwrap();
        assert!(m.pos_emb.bit_eq(&m2.pos_emb));
        assert!(m.tok_emb.weight.bit_eq(&m2.tok_emb.weight));
    }

    #[test]
    fn forward_and_loss_deterministic() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 1, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 2).unwrap();
        let ids = [1usize, 4, 2, 9, 3, 7];
        let run = || {
            let mut t = Tape::new();
            let mut b = Vec::new();
            let loss = m.loss_on_sequence(&mut t, &ids, &mut b).unwrap();
            t.backward(loss).unwrap();
            let gs: Vec<Tensor> = b.iter().map(|v| t.grad(*v).unwrap()).collect();
            (t.value(loss), gs, b.len())
        };
        let (l1, g1, n1) = run();
        let (l2, g2, _) = run();
        assert!(l1.bit_eq(&l2));
        assert_eq!(g1.len(), g2.len());
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!(a.bit_eq(b));
        }
        // binds order must match params_mut order (count check)
        let mut m2 = CharTransformer::new(cfg, 2).unwrap();
        assert_eq!(n1, m2.params_mut().len());
    }

    #[test]
    fn infer_logits_match_tape_forward_bitwise() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 9).unwrap();
        for ids in [&[1usize, 4, 2, 9, 3, 7][..], &[0usize][..], &[5usize, 5, 11][..]] {
            let mut t = Tape::new();
            let mut b = Vec::new();
            let want = t.value(m.forward_logits(&mut t, ids, &mut b).unwrap());
            for lanes in [1usize, 2, 4] {
                let pool = crate::tensor::WorkerPool::new(lanes);
                let got = m.forward_logits_infer_in(&pool, ids).unwrap();
                assert!(
                    got.bit_eq(&want),
                    "ids={ids:?} lanes={lanes}: off-tape transformer changed bits"
                );
            }
        }
        // serving-facing error paths: never panic
        let pool = crate::tensor::WorkerPool::new(1);
        assert!(m.forward_logits_infer_in(&pool, &[]).is_err(), "empty sequence");
        assert!(m.forward_logits_infer_in(&pool, &[0; 7]).is_err(), "over context");
        assert!(m.forward_logits_infer_in(&pool, &[12]).is_err(), "id ≥ vocab");
    }

    #[test]
    fn zero_mlp_ratio_is_a_construction_error() {
        // same policy as dim/heads/context/vocab = 0 (serving-facing)
        assert!(TransformerBlock::new(8, 2, 0, 1).is_err());
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 1, context: 6, mlp_ratio: 0 };
        assert!(CharTransformer::new(cfg, 2).is_err());
        assert!(TransformerBlock::new(8, 2, 1, 1).is_ok());
    }

    #[test]
    fn packed_forward_matches_unpacked_bitwise() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 13).unwrap();
        let ids = [1usize, 4, 2, 9, 3];
        for lanes in [1usize, 2] {
            let pool = crate::tensor::WorkerPool::new(lanes);
            let packed = m.pack_in(&pool).unwrap();
            let want = m.forward_logits_infer_in(&pool, &ids).unwrap();
            let got = m.forward_logits_packed_in(&pool, &ids, Some(&packed), None).unwrap();
            assert!(got.bit_eq(&want), "lanes={lanes}: packed transformer changed bits");
        }
    }

    #[test]
    fn step_decode_matches_full_recompute_for_every_prefix() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 21).unwrap();
        let ids = [1usize, 4, 2, 9, 3, 7];
        for lanes in [1usize, 2] {
            let pool = crate::tensor::WorkerPool::new(lanes);
            let packed = m.pack_in(&pool).unwrap();
            for use_packed in [false, true] {
                let p = use_packed.then_some(&packed);
                let mut kv = m.begin_kv();
                for t in 0..ids.len() {
                    let step =
                        m.forward_logits_step_packed_in(&pool, ids[t], &mut kv, p).unwrap();
                    assert_eq!(step.dims(), &[1, cfg.vocab]);
                    assert_eq!(kv.steps(), t + 1);
                    let full = m.forward_logits_infer_in(&pool, &ids[..t + 1]).unwrap();
                    let last = &full.data()[t * cfg.vocab..(t + 1) * cfg.vocab];
                    assert_eq!(
                        step.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        last.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "packed={use_packed} lanes={lanes} t={t}: step decode changed bits"
                    );
                }
                // context is full: one more step must be a typed error
                assert!(m.forward_logits_step_packed_in(&pool, 0, &mut kv, p).is_err());
            }
        }
    }

    #[test]
    fn prefill_capture_then_step_matches_full_recompute() {
        // the session flow: full forward over a prefix capturing KV,
        // then one incremental step — exactly what the serve tower does
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 33).unwrap();
        let ids = [5usize, 1, 11, 0, 7];
        let pool = crate::tensor::WorkerPool::new(2);
        for split in 1..ids.len() {
            let mut kv = m.begin_kv();
            let _ = m
                .forward_logits_packed_in(&pool, &ids[..split], None, Some(&mut kv))
                .unwrap();
            assert_eq!(kv.steps(), split);
            let step = m.forward_logits_step_infer_in(&pool, ids[split], &mut kv).unwrap();
            let full = m.forward_logits_infer_in(&pool, &ids[..split + 1]).unwrap();
            let last = &full.data()[split * cfg.vocab..(split + 1) * cfg.vocab];
            assert_eq!(
                step.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                last.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "split={split}: prefill-capture + step changed bits"
            );
        }
        // a used cache is rejected as prefill target
        let mut kv = m.begin_kv();
        let _ = m.forward_logits_packed_in(&pool, &ids[..2], None, Some(&mut kv)).unwrap();
        assert!(m
            .forward_logits_packed_in(&pool, &ids[..2], None, Some(&mut kv))
            .is_err());
    }

    #[test]
    fn sharded_logits_and_steps_are_tp_invariant() {
        // heads = 4 so tp ∈ {1,2,4} all divide the head count; the
        // sharded path's bits must be a pure function of (model, input)
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 4, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 51).unwrap();
        let ids = [1usize, 4, 2, 9, 3];
        // reference: tp=1 prefill — every other width must produce the
        // same logits AND be able to continue this very cache
        let pool1 = crate::tensor::WorkerPool::new(1);
        let shards1: Vec<_> = (0..1)
            .map(|s| m.pack_shard_in(&pool1, ShardPlan::new(1, s).unwrap()).unwrap())
            .collect();
        let mut kv0 = m.begin_kv();
        let want_full = m
            .forward_logits_sharded_in(&pool1, &ids[..3], &shards1, Some(&mut kv0))
            .unwrap();
        let mut want_step: Option<Vec<Vec<u32>>> = None;
        for tp in [1usize, 2, 4] {
            for lanes in [1usize, 2] {
                let pool = crate::tensor::WorkerPool::new(lanes);
                let shards: Vec<_> = (0..tp)
                    .map(|s| m.pack_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap())
                    .collect();
                let mut kv = m.begin_kv();
                let full = m
                    .forward_logits_sharded_in(&pool, &ids[..3], &shards, Some(&mut kv))
                    .unwrap();
                assert_eq!(kv.steps(), 3);
                assert!(
                    full.bit_eq(&want_full),
                    "tp={tp} lanes={lanes}: sharded logits changed bits"
                );
                // continue decoding from this width's own prefill…
                let mut steps = Vec::new();
                for &id in &ids[3..] {
                    let st = m.forward_logits_step_sharded_in(&pool, id, &shards, &mut kv).unwrap();
                    steps.push(st.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>());
                }
                // …and from the tp=1 prefill: caches transfer across TP
                // widths because the sharded graph's bits — including
                // every captured K/V row — are TP-invariant
                let mut kvx = kv0.clone();
                let mut steps_x = Vec::new();
                for &id in &ids[3..] {
                    let st =
                        m.forward_logits_step_sharded_in(&pool, id, &shards, &mut kvx).unwrap();
                    steps_x.push(st.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>());
                }
                assert_eq!(
                    steps, steps_x,
                    "tp={tp} lanes={lanes}: a tp=1 prefill cache diverged under tp={tp} decode"
                );
                match &want_step {
                    None => want_step = Some(steps),
                    Some(w) => assert_eq!(
                        w, &steps,
                        "tp={tp} lanes={lanes}: sharded step decode changed bits"
                    ),
                }
            }
        }
    }

    #[test]
    fn sharded_step_matches_sharded_full_recompute() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 4, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 63).unwrap();
        let ids = [5usize, 1, 11, 0, 7, 2];
        let pool = crate::tensor::WorkerPool::new(2);
        for tp in [1usize, 2] {
            let shards: Vec<_> = (0..tp)
                .map(|s| m.pack_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap())
                .collect();
            let mut kv = m.begin_kv();
            for t in 0..ids.len() {
                let step = m
                    .forward_logits_step_sharded_in(&pool, ids[t], &shards, &mut kv)
                    .unwrap();
                let full = m.forward_logits_sharded_in(&pool, &ids[..t + 1], &shards, None).unwrap();
                let last = &full.data()[t * cfg.vocab..(t + 1) * cfg.vocab];
                assert_eq!(
                    step.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    last.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "tp={tp} t={t}: sharded step diverged from sharded full forward"
                );
            }
            // context full: one more step is a typed error
            assert!(m.forward_logits_step_sharded_in(&pool, 0, &shards, &mut kv).is_err());
        }
    }

    #[test]
    fn sharded_construction_and_shard_set_errors() {
        let pool = crate::tensor::WorkerPool::new(1);
        // heads = 2 cannot split four ways
        let cfg2 = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 1, context: 6, mlp_ratio: 2 };
        let m2 = CharTransformer::new(cfg2, 1).unwrap();
        assert!(m2.pack_shard_in(&pool, ShardPlan::new(4, 0).unwrap()).is_err());
        assert!(m2.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).is_ok());
        // incomplete / out-of-order shard sets are forward errors
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 4, layers: 1, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 2).unwrap();
        let s0 = m.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        let s1 = m.pack_shard_in(&pool, ShardPlan::new(2, 1).unwrap()).unwrap();
        let ids = [1usize, 2];
        assert!(m.forward_logits_sharded_in(&pool, &ids, &[s1, s0], None).is_err(), "order");
        let s0 = m.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        assert!(m.forward_logits_sharded_in(&pool, &ids, &[s0], None).is_err(), "incomplete");
        assert!(m.forward_logits_sharded_in(&pool, &ids, &[], None).is_err(), "empty");
    }

    #[test]
    fn params_and_params_mut_agree_on_order() {
        let cfg = TransformerConfig { vocab: 9, dim: 8, heads: 2, layers: 2, context: 5, mlp_ratio: 2 };
        let mut m = CharTransformer::new(cfg, 4).unwrap();
        let immut: Vec<Vec<u32>> =
            m.params().iter().map(|p| p.data().iter().map(|v| v.to_bits()).collect()).collect();
        let muts: Vec<Vec<u32>> = m
            .params_mut()
            .iter()
            .map(|p| p.data().iter().map(|v| v.to_bits()).collect())
            .collect();
        assert_eq!(immut, muts, "params() must mirror params_mut() order");
    }

    #[test]
    fn tiny_training_reduces_loss() {
        let cfg = TransformerConfig { vocab: 8, dim: 8, heads: 2, layers: 1, context: 8, mlp_ratio: 2 };
        let mut m = CharTransformer::new(cfg, 3).unwrap();
        let ids = [1usize, 2, 3, 4, 5, 6, 7, 0];
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..40 {
            let mut t = Tape::new();
            let mut binds = Vec::new();
            let loss = m.loss_on_sequence(&mut t, &ids, &mut binds).unwrap();
            t.backward(loss).unwrap();
            let lv = t.value(loss).data()[0];
            if step == 0 {
                first = lv;
            }
            last = lv;
            let grads: Vec<Tensor> = binds.iter().map(|v| t.grad(*v).unwrap()).collect();
            for (p, g) in m.params_mut().into_iter().zip(grads.iter()) {
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data().iter()) {
                    *pv -= 0.05 * gv;
                }
            }
        }
        assert!(last < first, "loss {first} -> {last}");
    }
}
