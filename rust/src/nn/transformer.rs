//! A small GPT-style character transformer — the end-to-end training
//! workload (experiment E8). Pre-norm blocks, causal attention, GELU MLP,
//! learned positional embeddings; every sub-op is a RepDL fixed graph.

use super::{Embedding, LayerNorm, Linear, Module, MultiheadAttention};
use crate::autograd::{Tape, Var};
use crate::rng::derive_seed;
use crate::rnum::rgelu_tanh;
use crate::tensor::{Tensor, WorkerPool};
use crate::{Error, Result};

/// Transformer hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TransformerConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub dim: usize,
    /// Head count.
    pub heads: usize,
    /// Block count.
    pub layers: usize,
    /// Context length.
    pub context: usize,
    /// MLP expansion factor.
    pub mlp_ratio: usize,
}

impl Default for TransformerConfig {
    fn default() -> Self {
        TransformerConfig { vocab: 64, dim: 64, heads: 4, layers: 2, context: 32, mlp_ratio: 4 }
    }
}

/// Pre-norm transformer block.
pub struct TransformerBlock {
    /// First LayerNorm.
    pub ln1: LayerNorm,
    /// Attention.
    pub attn: MultiheadAttention,
    /// Second LayerNorm.
    pub ln2: LayerNorm,
    /// MLP up-projection.
    pub fc1: Linear,
    /// MLP down-projection.
    pub fc2: Linear,
}

impl TransformerBlock {
    /// New block.
    pub fn new(dim: usize, heads: usize, mlp_ratio: usize, seed: u64) -> Result<Self> {
        Ok(TransformerBlock {
            ln1: LayerNorm::new(dim),
            attn: MultiheadAttention::new(dim, heads, true, derive_seed(seed, 0))?,
            ln2: LayerNorm::new(dim),
            fc1: Linear::new(dim, dim * mlp_ratio, derive_seed(seed, 1)),
            fc2: Linear::new(dim * mlp_ratio, dim, derive_seed(seed, 2)),
        })
    }
}

impl TransformerBlock {
    /// Off-tape inference forward on a (T, D) sequence: the same
    /// pre-norm graph as [`Module::forward`] — LN → attention →
    /// residual, LN → GELU MLP → residual — through the off-tape layer
    /// forwards ([`LayerNorm::forward_infer`],
    /// [`MultiheadAttention::forward_seq_infer_in`],
    /// [`Linear::forward_infer_in`]) with no tape node allocation.
    /// Bit-identical to the tape forward (asserted in tests).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let h = self.ln1.forward_infer(x)?;
        let h = self.attn.forward_seq_infer_in(pool, &h)?;
        let x = x.add_t(&h)?; // residual
        let h = self.ln2.forward_infer(&x)?;
        let h = self.fc1.forward_infer_in(pool, &h)?;
        let h = h.map(rgelu_tanh); // same elementwise graph as Tape::gelu
        let h = self.fc2.forward_infer_in(pool, &h)?;
        x.add_t(&h) // residual
    }
}

impl Module for TransformerBlock {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let h = self.ln1.forward(t, x, binds)?;
        let h = self.attn.forward_seq(t, h, binds)?;
        let x = t.add(x, h)?; // residual
        let h = self.ln2.forward(t, x, binds)?;
        let h = self.fc1.forward(t, h, binds)?;
        let h = t.gelu(h);
        let h = self.fc2.forward(t, h, binds)?;
        t.add(x, h) // residual
    }

    fn params(&self) -> Vec<&Tensor> {
        let mut p = self.ln1.params();
        p.extend(self.attn.params());
        p.extend(self.ln2.params());
        p.extend(self.fc1.params());
        p.extend(self.fc2.params());
        p
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.ln1.params_mut();
        p.extend(self.attn.params_mut());
        p.extend(self.ln2.params_mut());
        p.extend(self.fc1.params_mut());
        p.extend(self.fc2.params_mut());
        p
    }
}

/// GPT-style char LM.
pub struct CharTransformer {
    /// Config.
    pub cfg: TransformerConfig,
    /// Token embedding.
    pub tok_emb: Embedding,
    /// Positional embedding (context, dim) as a raw parameter.
    pub pos_emb: Tensor,
    /// Blocks.
    pub blocks: Vec<TransformerBlock>,
    /// Final LayerNorm.
    pub ln_f: LayerNorm,
    /// LM head (vocab logits).
    pub head: Linear,
}

impl CharTransformer {
    /// Build with reproducible init.
    pub fn new(cfg: TransformerConfig, seed: u64) -> Result<Self> {
        let blocks = (0..cfg.layers)
            .map(|i| TransformerBlock::new(cfg.dim, cfg.heads, cfg.mlp_ratio, derive_seed(seed, 10 + i as u64)))
            .collect::<Result<Vec<_>>>()?;
        Ok(CharTransformer {
            cfg,
            tok_emb: Embedding::new(cfg.vocab, cfg.dim, 0.02, derive_seed(seed, 0)),
            pos_emb: crate::rng::normal_tensor(&[cfg.context, cfg.dim], 0.0, 0.02, derive_seed(seed, 1)),
            blocks,
            ln_f: LayerNorm::new(cfg.dim),
            head: Linear::new(cfg.dim, cfg.vocab, derive_seed(seed, 2)),
        })
    }

    /// Forward one sequence of token ids (≤ context) to (T, vocab) logits.
    pub fn forward_logits(&self, t: &mut Tape, ids: &[usize], binds: &mut Vec<Var>) -> Result<Var> {
        let tt = ids.len();
        let e = self.tok_emb.forward(t, ids, binds)?; // (T, D)
        let pe = t.param(self.pos_emb.clone());
        binds.push(pe);
        let pe_t = t.slice_rows(pe, 0, tt)?;
        let mut h = t.add(e, pe_t)?;
        for b in &self.blocks {
            h = b.forward(t, h, binds)?;
        }
        let h = self.ln_f.forward(t, h, binds)?;
        self.head.forward(t, h, binds)
    }

    /// Next-token cross-entropy over one sequence:
    /// inputs ids[0..T−1], targets ids[1..T].
    pub fn loss_on_sequence(&self, t: &mut Tape, ids: &[usize], binds: &mut Vec<Var>) -> Result<Var> {
        let inputs = &ids[..ids.len() - 1];
        let targets = &ids[1..];
        let logits = self.forward_logits(t, inputs, binds)?;
        t.softmax_cross_entropy(logits, targets)
    }

    /// Off-tape inference forward on an explicit pool: one sequence of
    /// token ids (`0 < len ≤ context`) to (T, vocab) logits, with **no
    /// `Tape` allocation** — embedding lookup and the positional-row
    /// slice are plain row copies (layout-only), the blocks run
    /// [`TransformerBlock::forward_infer_in`], and the head is a pooled
    /// GEMM. Every op follows the identical fixed graph as
    /// [`Self::forward_logits`], so the logits are bit-identical to the
    /// tape forward (asserted in tests and pinned against the
    /// independent Python emulator in `tests/golden_vectors.rs`).
    /// Serving-facing: out-of-range ids and bad lengths are errors,
    /// never panics.
    pub fn forward_logits_infer_in(&self, pool: &WorkerPool, ids: &[usize]) -> Result<Tensor> {
        let tt = ids.len();
        if tt == 0 || tt > self.cfg.context {
            return Err(Error::shape(format!(
                "transformer infer: sequence length {tt} not in 1..={}",
                self.cfg.context
            )));
        }
        let dim = self.cfg.dim;
        let table = &self.tok_emb.weight;
        for &i in ids {
            if i >= self.cfg.vocab {
                return Err(Error::shape(format!(
                    "transformer infer: id {i} ≥ vocab {}",
                    self.cfg.vocab
                )));
            }
        }
        // token embedding + positional rows (both layout-only lookups)
        let mut e = Tensor::zeros(&[tt, dim]);
        for (r, &i) in ids.iter().enumerate() {
            e.data_mut()[r * dim..(r + 1) * dim]
                .copy_from_slice(&table.data()[i * dim..(i + 1) * dim]);
        }
        let mut pe = Tensor::zeros(&[tt, dim]);
        pe.data_mut().copy_from_slice(&self.pos_emb.data()[..tt * dim]);
        let mut h = e.add_t(&pe)?;
        for b in &self.blocks {
            h = b.forward_infer_in(pool, &h)?;
        }
        let h = self.ln_f.forward_infer(&h)?;
        self.head.forward_infer_in(pool, &h)
    }

    /// All parameters in fixed traversal order (same order as
    /// [`Self::params_mut`] — the model-state fingerprint and the serve
    /// tower's `weights_hash` both rely on it).
    pub fn params(&self) -> Vec<&Tensor> {
        let mut p = self.tok_emb.params();
        p.push(&self.pos_emb);
        for b in &self.blocks {
            p.extend(b.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }

    /// All parameters in fixed traversal order (must match forward
    /// registration order — asserted in tests).
    pub fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.tok_emb.params_mut();
        p.push(&mut self.pos_emb);
        for b in &mut self.blocks {
            p.extend(b.params_mut());
        }
        p.extend(self.ln_f.params_mut());
        p.extend(self.head.params_mut());
        p
    }

    /// Parameter count.
    pub fn num_params(&self) -> usize {
        let mut n = self.tok_emb.weight.numel() + self.pos_emb.numel();
        for b in &self.blocks {
            n += b.num_params();
        }
        n += self.ln_f.num_params() + self.head.num_params();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_counts_params() {
        let cfg = TransformerConfig { vocab: 20, dim: 16, heads: 2, layers: 2, context: 8, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 1).unwrap();
        assert!(m.num_params() > 4000, "n={}", m.num_params());
        // init reproducible
        let m2 = CharTransformer::new(cfg, 1).unwrap();
        assert!(m.pos_emb.bit_eq(&m2.pos_emb));
        assert!(m.tok_emb.weight.bit_eq(&m2.tok_emb.weight));
    }

    #[test]
    fn forward_and_loss_deterministic() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 1, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 2).unwrap();
        let ids = [1usize, 4, 2, 9, 3, 7];
        let run = || {
            let mut t = Tape::new();
            let mut b = Vec::new();
            let loss = m.loss_on_sequence(&mut t, &ids, &mut b).unwrap();
            t.backward(loss).unwrap();
            let gs: Vec<Tensor> = b.iter().map(|v| t.grad(*v).unwrap()).collect();
            (t.value(loss), gs, b.len())
        };
        let (l1, g1, n1) = run();
        let (l2, g2, _) = run();
        assert!(l1.bit_eq(&l2));
        assert_eq!(g1.len(), g2.len());
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!(a.bit_eq(b));
        }
        // binds order must match params_mut order (count check)
        let mut m2 = CharTransformer::new(cfg, 2).unwrap();
        assert_eq!(n1, m2.params_mut().len());
    }

    #[test]
    fn infer_logits_match_tape_forward_bitwise() {
        let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 2, layers: 2, context: 6, mlp_ratio: 2 };
        let m = CharTransformer::new(cfg, 9).unwrap();
        for ids in [&[1usize, 4, 2, 9, 3, 7][..], &[0usize][..], &[5usize, 5, 11][..]] {
            let mut t = Tape::new();
            let mut b = Vec::new();
            let want = t.value(m.forward_logits(&mut t, ids, &mut b).unwrap());
            for lanes in [1usize, 2, 4] {
                let pool = crate::tensor::WorkerPool::new(lanes);
                let got = m.forward_logits_infer_in(&pool, ids).unwrap();
                assert!(
                    got.bit_eq(&want),
                    "ids={ids:?} lanes={lanes}: off-tape transformer changed bits"
                );
            }
        }
        // serving-facing error paths: never panic
        let pool = crate::tensor::WorkerPool::new(1);
        assert!(m.forward_logits_infer_in(&pool, &[]).is_err(), "empty sequence");
        assert!(m.forward_logits_infer_in(&pool, &[0; 7]).is_err(), "over context");
        assert!(m.forward_logits_infer_in(&pool, &[12]).is_err(), "id ≥ vocab");
    }

    #[test]
    fn params_and_params_mut_agree_on_order() {
        let cfg = TransformerConfig { vocab: 9, dim: 8, heads: 2, layers: 2, context: 5, mlp_ratio: 2 };
        let mut m = CharTransformer::new(cfg, 4).unwrap();
        let immut: Vec<Vec<u32>> =
            m.params().iter().map(|p| p.data().iter().map(|v| v.to_bits()).collect()).collect();
        let muts: Vec<Vec<u32>> = m
            .params_mut()
            .iter()
            .map(|p| p.data().iter().map(|v| v.to_bits()).collect())
            .collect();
        assert_eq!(immut, muts, "params() must mirror params_mut() order");
    }

    #[test]
    fn tiny_training_reduces_loss() {
        let cfg = TransformerConfig { vocab: 8, dim: 8, heads: 2, layers: 1, context: 8, mlp_ratio: 2 };
        let mut m = CharTransformer::new(cfg, 3).unwrap();
        let ids = [1usize, 2, 3, 4, 5, 6, 7, 0];
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..40 {
            let mut t = Tape::new();
            let mut binds = Vec::new();
            let loss = m.loss_on_sequence(&mut t, &ids, &mut binds).unwrap();
            t.backward(loss).unwrap();
            let lv = t.value(loss).data()[0];
            if step == 0 {
                first = lv;
            }
            last = lv;
            let grads: Vec<Tensor> = binds.iter().map(|v| t.grad(*v).unwrap()).collect();
            for (p, g) in m.params_mut().into_iter().zip(grads.iter()) {
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data().iter()) {
                    *pv -= 0.05 * gv;
                }
            }
        }
        assert!(last < first, "loss {first} -> {last}");
    }
}
