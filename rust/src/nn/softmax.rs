//! Softmax as a fixed computation graph (paper §3.2.3).
//!
//! The graph is pinned: row max (canonical [`max_wins`] rule: NaN wins,
//! first occurrence kept — shared with `tensor::max_axis` since the
//! NaN-rule unification migration, DESIGN.md §8) → subtract → `rexp`
//! (correctly rounded) → **sequential** sum → divide. A log-softmax with
//! its own graph gets its own name.
//!
//! A NaN anywhere in a row therefore makes the row max NaN, and every
//! output of that row is NaN with a deterministic propagation path —
//! before the migration the max silently skipped NaNs and the poisoning
//! went through the sum instead, a bit-level divergence from the
//! documented rule.

use crate::rnum::{rexp, rlog};
use crate::tensor::{max_wins, Tensor};
use crate::{Error, Result};

/// Reject rank ≠ 2 and zero-length rows: a row of no logits has no
/// maximum and an all-zero denominator, so `(R, 0)` is a shape error
/// (the seed read `w[0]` and panicked) — same error-not-panic policy as
/// the degenerate reductions in `tensor/reduce.rs`.
fn check_rows(x: &Tensor, name: &str) -> Result<(usize, usize)> {
    let d = x.dims();
    if d.len() != 2 {
        return Err(Error::shape(format!("{name}: want rank 2")));
    }
    if d[1] == 0 {
        return Err(Error::shape(format!("{name}: zero-length rows in {d:?}")));
    }
    Ok((d[0], d[1]))
}

/// Row-wise softmax over the last axis of a 2-D tensor.
pub fn softmax_rows(x: &Tensor) -> Result<Tensor> {
    let (rows, c) = check_rows(x, "softmax_rows")?;
    let mut out = Tensor::zeros(x.dims());
    for r in 0..rows {
        let w = x.row(r);
        let mut m = w[0];
        for &v in &w[1..] {
            if max_wins(v, m) {
                m = v;
            }
        }
        let mut denom = 0.0f32;
        for j in 0..c {
            let e = rexp(w[j] - m);
            out.data_mut()[r * c + j] = e;
            denom += e;
        }
        for j in 0..c {
            out.data_mut()[r * c + j] /= denom;
        }
    }
    Ok(out)
}

/// Row-wise log-softmax: `x − m − rlog(Σ rexp(x − m))` (a *different*
/// fixed graph from `log(softmax(x))`, hence its own API).
pub fn log_softmax_rows(x: &Tensor) -> Result<Tensor> {
    let (rows, c) = check_rows(x, "log_softmax_rows")?;
    let mut out = Tensor::zeros(x.dims());
    for r in 0..rows {
        let w = x.row(r);
        let mut m = w[0];
        for &v in &w[1..] {
            if max_wins(v, m) {
                m = v;
            }
        }
        let mut denom = 0.0f32;
        for j in 0..c {
            denom += rexp(w[j] - m);
        }
        let lse = rlog(denom);
        for j in 0..c {
            out.data_mut()[r * c + j] = w[j] - m - lse;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1.]).unwrap();
        let s = softmax_rows(&x).unwrap();
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // monotone with logits
        assert!(s.at(&[0, 2]) > s.at(&[0, 1]));
    }

    #[test]
    fn shift_invariance_exact_for_equal_rows() {
        // softmax(x) == softmax(x + c) exactly when x − max is unchanged —
        // here both rows reduce to the same shifted values, so bits match.
        let a = Tensor::from_vec(&[1, 3], vec![1., 2., 3.]).unwrap();
        let b = Tensor::from_vec(&[1, 3], vec![11., 12., 13.]).unwrap();
        let (sa, sb) = (softmax_rows(&a).unwrap(), softmax_rows(&b).unwrap());
        assert!(sa.bit_eq(&sb));
    }

    #[test]
    fn log_softmax_close_to_log_of_softmax_but_distinct_graph() {
        let x = Tensor::from_vec(&[1, 4], vec![0.3, -1.2, 2.0, 0.0]).unwrap();
        let ls = log_softmax_rows(&x).unwrap();
        let s = softmax_rows(&x).unwrap();
        for j in 0..4 {
            assert!((ls.data()[j] - rlog(s.data()[j])).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_length_rows_error_instead_of_panicking() {
        let degenerate = Tensor::zeros(&[3, 0]);
        assert!(softmax_rows(&degenerate).is_err());
        assert!(log_softmax_rows(&degenerate).is_err());
        // zero *rows* with non-empty columns stay fine: nothing is read
        let empty = Tensor::zeros(&[0, 4]);
        assert_eq!(softmax_rows(&empty).unwrap().numel(), 0);
        assert_eq!(log_softmax_rows(&empty).unwrap().numel(), 0);
    }

    #[test]
    fn nan_rows_poison_deterministically() {
        // row max is max_wins (NaN wins, first occurrence), so a single
        // NaN makes the whole row NaN through `x − NaN`, and an all-NaN
        // row stays all-NaN — no panic, no partial row
        for row in [
            vec![1.0f32, f32::NAN, 2.0],
            vec![f32::NAN, 5.0, -1.0],
            vec![f32::NAN, f32::NAN, f32::NAN],
        ] {
            let x = Tensor::from_vec(&[1, 3], row.clone()).unwrap();
            let s = softmax_rows(&x).unwrap();
            let ls = log_softmax_rows(&x).unwrap();
            assert!(s.data().iter().all(|v| v.is_nan()), "softmax {row:?}");
            assert!(ls.data().iter().all(|v| v.is_nan()), "log_softmax {row:?}");
            // bit-deterministic across calls, NaN payloads included
            assert!(s.bit_eq(&softmax_rows(&x).unwrap()));
            assert!(ls.bit_eq(&log_softmax_rows(&x).unwrap()));
        }
        // finite rows are untouched by the migration (max_wins == `v > m`
        // on finite data): a clean row next to a NaN row stays clean
        let x = Tensor::from_vec(&[2, 3], vec![1., f32::NAN, 2., 0.5, 1.5, -0.5]).unwrap();
        let s = softmax_rows(&x).unwrap();
        assert!(s.row(0).iter().all(|v| v.is_nan()));
        let clean = Tensor::from_vec(&[1, 3], vec![0.5, 1.5, -0.5]).unwrap();
        assert_eq!(s.row(1), softmax_rows(&clean).unwrap().row(0));
    }

    #[test]
    fn deterministic() {
        let x = Tensor::from_vec(&[1, 5], vec![0.1, 0.2, 0.3, 0.4, 0.5]).unwrap();
        assert!(softmax_rows(&x).unwrap().bit_eq(&softmax_rows(&x).unwrap()));
        assert!(log_softmax_rows(&x).unwrap().bit_eq(&log_softmax_rows(&x).unwrap()));
    }
}
