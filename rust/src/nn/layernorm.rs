//! `nn::LayerNorm` — normalisation over the last axis, fixed two-pass
//! graph with `rrsqrt` (see `Tape::layer_norm` for the spec).

use super::Module;
use crate::autograd::{Tape, Var};
use crate::tensor::Tensor;
use crate::Result;

/// Layer normalisation with affine parameters.
pub struct LayerNorm {
    /// γ (scale).
    pub weight: Tensor,
    /// β (shift).
    pub bias: Tensor,
    /// Numerical epsilon.
    pub eps: f32,
}

impl LayerNorm {
    /// PyTorch defaults: γ=1, β=0, eps=1e−5.
    pub fn new(dim: usize) -> Self {
        LayerNorm {
            weight: Tensor::full(&[dim], 1.0),
            bias: Tensor::zeros(&[dim]),
            eps: 1e-5,
        }
    }
}

impl Module for LayerNorm {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let g = t.param(self.weight.clone());
        let b = t.param(self.bias.clone());
        binds.push(g);
        binds.push(b);
        t.layer_norm(x, g, b, self.eps)
    }

    fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight, &mut self.bias]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalises_rows() {
        let ln = LayerNorm::new(4);
        let x = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]).unwrap();
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let y = ln.forward(&mut t, xv, &mut binds).unwrap();
        let v = t.value(y);
        for r in 0..2 {
            let row = v.row(r);
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn deterministic_forward() {
        let ln = LayerNorm::new(8);
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32).sin()).collect()).unwrap();
        let run = || {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let y = ln.forward(&mut t, xv, &mut b).unwrap();
            t.value(y)
        };
        assert!(run().bit_eq(&run()));
    }
}
