//! `nn::LayerNorm` — normalisation over the last axis, fixed two-pass
//! graph with `rrsqrt` (see `Tape::layer_norm` for the spec).

use super::Module;
use crate::autograd::{Tape, Var};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Off-tape LayerNorm forward over the last axis — the same fixed
/// two-pass graph as `Tape::layer_norm` (sequential mean sum, sequential
/// squared-deviation sum, `rrsqrt(var + eps)` per row, then
/// `x̂·γ + β`), without any tape node allocation. Bit-identical to the
/// tape forward (asserted in tests); serving inference towers call this
/// per request.
pub fn layer_norm_forward(x: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Result<Tensor> {
    let d = x.dims();
    let n = *d.last().ok_or_else(|| Error::shape("layer_norm: scalar input"))?;
    if n == 0 {
        // error, not a divide-by-zero panic — the degenerate-shape
        // policy every zero-axis kernel follows (DESIGN §7)
        return Err(Error::shape("layer_norm: zero-length last axis"));
    }
    if gamma.dims() != [n] || beta.dims() != [n] {
        return Err(Error::shape("layer_norm: γ/β must match last axis"));
    }
    let rows = x.numel() / n;
    let mut out = Tensor::zeros(d);
    for r in 0..rows {
        let w = &x.data()[r * n..(r + 1) * n];
        let mut s = 0.0f32;
        for &v in w {
            s += v;
        }
        let mu = s / n as f32;
        let mut v2 = 0.0f32;
        for &v in w {
            let dd = v - mu;
            v2 += dd * dd;
        }
        let var = v2 / n as f32;
        let rs = crate::rnum::rrsqrt(var + eps);
        for j in 0..n {
            let xh = (w[j] - mu) * rs;
            out.data_mut()[r * n + j] = xh * gamma.data()[j] + beta.data()[j];
        }
    }
    Ok(out)
}

/// Layer normalisation with affine parameters.
pub struct LayerNorm {
    /// γ (scale).
    pub weight: Tensor,
    /// β (shift).
    pub bias: Tensor,
    /// Numerical epsilon.
    pub eps: f32,
}

impl LayerNorm {
    /// PyTorch defaults: γ=1, β=0, eps=1e−5.
    pub fn new(dim: usize) -> Self {
        LayerNorm {
            weight: Tensor::full(&[dim], 1.0),
            bias: Tensor::zeros(&[dim]),
            eps: 1e-5,
        }
    }

    /// Off-tape inference forward (see [`layer_norm_forward`]).
    pub fn forward_infer(&self, x: &Tensor) -> Result<Tensor> {
        layer_norm_forward(x, &self.weight, &self.bias, self.eps)
    }
}

impl Module for LayerNorm {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let g = t.param(self.weight.clone());
        let b = t.param(self.bias.clone());
        binds.push(g);
        binds.push(b);
        t.layer_norm(x, g, b, self.eps)
    }

    fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight, &mut self.bias]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalises_rows() {
        let ln = LayerNorm::new(4);
        let x = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]).unwrap();
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let y = ln.forward(&mut t, xv, &mut binds).unwrap();
        let v = t.value(y);
        for r in 0..2 {
            let row = v.row(r);
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise() {
        let mut ln = LayerNorm::new(5);
        // non-trivial affine params so γ/β order errors cannot hide
        for (i, v) in ln.weight.data_mut().iter_mut().enumerate() {
            *v = 0.5 + i as f32 * 0.25;
        }
        for (i, v) in ln.bias.data_mut().iter_mut().enumerate() {
            *v = (i as f32 - 2.0) * 0.125;
        }
        let x = Tensor::from_vec(&[4, 5], (0..20).map(|i| (i as f32 * 0.37).sin()).collect())
            .unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut b = Vec::new();
        let want = t.value(ln.forward(&mut t, xv, &mut b).unwrap());
        let got = ln.forward_infer(&x).unwrap();
        assert!(got.bit_eq(&want), "off-tape LayerNorm changed bits");
        // scalar input is a shape error, matching the tape op
        assert!(layer_norm_forward(&Tensor::scalar(1.0), &ln.weight, &ln.bias, ln.eps).is_err());
        // zero-length last axis errors instead of dividing by zero
        let z = Tensor::zeros(&[0]);
        assert!(layer_norm_forward(&Tensor::zeros(&[3, 0]), &z, &z, ln.eps).is_err());
    }

    #[test]
    fn deterministic_forward() {
        let ln = LayerNorm::new(8);
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32).sin()).collect()).unwrap();
        let run = || {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let y = ln.forward(&mut t, xv, &mut b).unwrap();
            t.value(y)
        };
        assert!(run().bit_eq(&run()));
    }
}
