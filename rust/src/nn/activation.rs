//! Stateless activation modules (`nn::ReLU`, `nn::GELU`, `nn::Tanh`,
//! `nn::Sigmoid`) — thin module wrappers over the tape ops.

use super::Module;
use crate::autograd::{Tape, Var};
use crate::tensor::Tensor;
use crate::Result;

macro_rules! activation_module {
    ($name:ident, $method:ident, $doc:literal) => {
        #[doc = $doc]
        #[derive(Default, Clone, Copy)]
        pub struct $name;

        impl Module for $name {
            fn forward(&self, t: &mut Tape, x: Var, _binds: &mut Vec<Var>) -> Result<Var> {
                Ok(t.$method(x))
            }
            fn params(&self) -> Vec<&Tensor> {
                Vec::new()
            }
            fn params_mut(&mut self) -> Vec<&mut Tensor> {
                Vec::new()
            }
        }
    };
}

activation_module!(ReLU, relu, "Rectified linear unit.");
activation_module!(GELU, gelu, "GELU (tanh graph — see `rnum::special`).");
activation_module!(Tanh, tanh, "Correctly-rounded tanh.");
activation_module!(Sigmoid, sigmoid, "Sigmoid (fixed graph).");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_module() {
        let mut t = Tape::new();
        let x = t.input(Tensor::from_vec(&[3], vec![-1., 0., 2.]).unwrap());
        let mut b = Vec::new();
        let y = ReLU.forward(&mut t, x, &mut b).unwrap();
        assert_eq!(t.value(y).data(), &[0., 0., 2.]);
        assert!(b.is_empty());
        assert_eq!(ReLU.num_params(), 0);
    }

    #[test]
    fn all_activations_run() {
        let x = Tensor::from_vec(&[4], vec![-2., -0.5, 0.5, 2.]).unwrap();
        for (name, m) in [
            ("gelu", &GELU as &dyn Module),
            ("tanh", &Tanh),
            ("sigmoid", &Sigmoid),
        ] {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let y = m.forward(&mut t, xv, &mut b).unwrap();
            assert_eq!(t.value(y).dims(), &[4], "{name}");
        }
    }
}
