//! `nn::Embedding` — token-id lookup with deterministic scatter-add
//! backward (the paper's §2.2.2 atomic-scatter hazard, fixed).

use crate::autograd::{Tape, Var};
use crate::rng::normal_tensor;
use crate::tensor::Tensor;
use crate::Result;

/// Embedding table (V, D).
pub struct Embedding {
    /// The table parameter.
    pub weight: Tensor,
}

impl Embedding {
    /// N(0, 1) init scaled like PyTorch's default (std=1) — callers
    /// usually rescale; transformer uses std=0.02.
    pub fn new(vocab: usize, dim: usize, std: f32, seed: u64) -> Self {
        Embedding { weight: normal_tensor(&[vocab, dim], 0.0, std, seed) }
    }

    /// Look up `ids`, registering the table on the tape.
    pub fn forward(&self, t: &mut Tape, ids: &[usize], binds: &mut Vec<Var>) -> Result<Var> {
        let w = t.param(self.weight.clone());
        binds.push(w);
        t.embedding(w, ids)
    }

    /// Parameters (fixed order — just the table).
    pub fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight]
    }

    /// Mutable parameters.
    pub fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_rows() {
        let e = Embedding::new(5, 3, 0.02, 1);
        let mut t = Tape::new();
        let mut b = Vec::new();
        let y = e.forward(&mut t, &[2, 2, 4], &mut b).unwrap();
        let v = t.value(y);
        assert_eq!(v.dims(), &[3, 3]);
        assert_eq!(v.row(0), &e.weight.data()[6..9]);
        assert_eq!(v.row(0), v.row(1));
        assert!(e.forward(&mut t, &[9], &mut b).is_err());
    }
}
