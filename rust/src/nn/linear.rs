//! `nn::Linear` — y = x Wᵀ + b, PyTorch parameter layout (out, in).
//!
//! The GEMM is the RepDL sequential-k spec; the transpose is a layout
//! operation only (bit-neutral, see `tensor::matmul`).

use super::Module;
use crate::autograd::{Tape, Var};
use crate::rng::{derive_seed, kaiming_uniform, uniform_tensor};
use crate::rnum::{fixed_tree_reduce_into, rrsqrt};
use crate::tensor::microkernel::{gemm_packed_into, pack_b_panels, packed_b_len};
use crate::tensor::{matmul_in, Tensor, WorkerPool};
use crate::{Error, Result};

/// How many **logical** partial sums a row-split layer decomposes into —
/// always, at every tensor-parallel width. A row-split GEMM's k dimension
/// divides into this many equal contiguous segments; each physical shard
/// owns `TP_LOGICAL_PARTS / tp` of them and emits **one partial per
/// logical segment** (never one per shard), and the partials combine in
/// the fixed pairwise tree over the logical segment index
/// ([`crate::rnum::reduce`]). The reduction graph is therefore a pure
/// function of the layer shape — TP width only moves segments between
/// workers, so TP ∈ {1, 2, 4} produce identical bits (DESIGN.md §13).
/// This is the tensor-parallel analogue of `DataParallelTrainer`'s fixed
/// microbatch count: physical lanes vary, the logical decomposition does
/// not.
pub const TP_LOGICAL_PARTS: usize = 4;

/// Fully-connected layer.
pub struct Linear {
    /// Weight, shape (out_features, in_features) — PyTorch layout.
    pub weight: Tensor,
    /// Bias, shape (out_features,).
    pub bias: Tensor,
}

impl Linear {
    /// PyTorch-default init: Kaiming-uniform weight, U(−1/√in, 1/√in) bias.
    pub fn new(in_features: usize, out_features: usize, seed: u64) -> Self {
        let weight = kaiming_uniform(&[out_features, in_features], derive_seed(seed, 0));
        let bound = rrsqrt(in_features as f32);
        let bias = uniform_tensor(&[out_features], -bound, bound, derive_seed(seed, 1));
        Linear { weight, bias }
    }

    /// Off-tape inference forward on an explicit pool: `x Wᵀ + b` with no
    /// `Tape` node allocation. Same fixed graph as [`Module::forward`] —
    /// the transpose is layout-only and [`matmul_in`] computes the
    /// identical sequential-k unfused spec on any pool size — so the bits
    /// match the tape forward exactly (asserted in tests).
    ///
    /// The transpose is re-materialised per call because `weight` is
    /// mutable during training (`params_mut`) and this layer cannot know
    /// when it changes. Serving towers whose weights are frozen at
    /// construction pack W once instead via [`Linear::pack_in`] —
    /// layout-only, bit-identical (asserted in tests).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let wt = self.weight.transpose2d()?; // (in, out)
        matmul_in(pool, x, &wt)?.add_t(&self.bias)
    }

    /// Freeze this layer's weights into microkernel panels (the
    /// `DeterministicServer` trick): transpose **once**, pack **once**,
    /// and serve every subsequent request with zero per-call transpose
    /// or packing allocations. The snapshot is taken now — training this
    /// layer afterwards does not update the pack.
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedLinear> {
        let wt = self.weight.transpose2d()?; // (in, out), materialised once
        let (k, n) = (wt.dims()[0], wt.dims()[1]);
        let mut packed = vec![0.0f32; packed_b_len(k, n)];
        pack_b_panels(pool, wt.data(), k, n, &mut packed);
        Ok(PackedLinear { packed, bias: self.bias.clone(), d_in: k, d_out: n })
    }
}

/// A [`Linear`] frozen for serving: Wᵀ pre-packed into [`NR`-wide
/// microkernel panels](crate::tensor::microkernel) at construction.
///
/// Bit-neutrality: packing is layout-only, the packed GEMM keeps every
/// output element's sequential-k mul/add graph (`packed == blocked ==
/// dotform`, asserted in `tensor/microkernel.rs`), and the bias is added
/// per column with exactly one `+` per element after the reduction —
/// the identical graph `matmul_in(x, Wᵀ) + b` builds. So
/// [`PackedLinear::forward_infer_in`] ==
/// [`Linear::forward_infer_in`] bit for bit (asserted in tests), with
/// zero per-call transpose/pack allocations.
pub struct PackedLinear {
    packed: Vec<f32>,
    bias: Tensor,
    d_in: usize,
    d_out: usize,
}

impl PackedLinear {
    /// Input features.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output features.
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// `x Wᵀ + b` on (m, d_in) input through the pre-packed panels.
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let d = x.dims();
        if d.len() != 2 || d[1] != self.d_in {
            return Err(Error::shape(format!(
                "PackedLinear: want (m, {}), got {d:?}",
                self.d_in
            )));
        }
        let (m, k, n) = (d[0], self.d_in, self.d_out);
        let bias = self.bias.data();
        Ok(Tensor::filled_by(&[m, n], |buf| {
            gemm_packed_into(pool, x.data(), m, k, &self.packed, n, None, false, buf);
            // per-column bias, one add per element after the reduction —
            // the same graph as `add_t`'s (m,n)+(n,) broadcast
            for row in buf.chunks_exact_mut(n) {
                for (v, b) in row.iter_mut().zip(bias.iter()) {
                    *v = *v + *b;
                }
            }
        }))
    }
}

/// One shard's coordinates in a tensor-parallel plan: `tp` shards,
/// this one at index `shard`. Validated at construction — `tp` must be
/// ≥ 1, must divide [`TP_LOGICAL_PARTS`] (so every shard owns the same
/// whole number of contiguous logical segments), and `shard < tp`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Tensor-parallel width (total shard count).
    pub tp: usize,
    /// This shard's index, in `0..tp`.
    pub shard: usize,
}

impl ShardPlan {
    /// Validated plan (serving-facing: errors, never panics).
    pub fn new(tp: usize, shard: usize) -> Result<Self> {
        if tp == 0 {
            return Err(Error::config("shard plan: tp must be ≥ 1"));
        }
        if TP_LOGICAL_PARTS % tp != 0 {
            return Err(Error::config(format!(
                "shard plan: tp {tp} must divide the logical partial count {TP_LOGICAL_PARTS}"
            )));
        }
        if shard >= tp {
            return Err(Error::config(format!("shard plan: shard {shard} ≥ tp {tp}")));
        }
        Ok(ShardPlan { tp, shard })
    }

    /// Logical k-segments this shard owns: `(first, count)` with the
    /// shard covering segments `first .. first + count` — contiguous, in
    /// logical order, the same blocks at every tp.
    pub fn owned_segments(&self) -> (usize, usize) {
        let per = TP_LOGICAL_PARTS / self.tp;
        (self.shard * per, per)
    }
}

/// Which way a [`PackedLinearShard`] splits the weight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SplitKind {
    /// Output-column split: this shard computes a contiguous slice of
    /// output features, full-k GEMM, local bias slice. Layout-only —
    /// concatenating the shard outputs in shard order reproduces the
    /// unsharded bits exactly (each output element's sequential-k
    /// mul/add graph is untouched).
    Col,
    /// Input-row (k) split: this shard computes one full-width partial
    /// product per owned logical segment; the partials combine through
    /// the fixed pairwise tree ([`reduce_row_partials`]), bias added
    /// exactly once after the tree.
    Row,
}

/// One tensor-parallel shard of a [`Linear`], frozen for serving
/// (microkernel panels, like [`PackedLinear`]). Built by
/// [`Linear::pack_col_shard_in`] / [`Linear::pack_row_shard_in`].
pub struct PackedLinearShard {
    kind: SplitKind,
    /// Row split: one packed panel set per owned logical segment, in
    /// logical order. Column split: one full-k panel set.
    segs: Vec<Vec<f32>>,
    /// k per panel set: full `d_in` (col) or `d_in / TP_LOGICAL_PARTS`
    /// (row).
    seg_k: usize,
    /// Full input width of the unsharded layer.
    d_in: usize,
    /// Output width of one GEMM: the shard's column-slice width (col) or
    /// the full output width (row — every partial spans all columns).
    d_out: usize,
    /// Col: this shard's bias slice (added locally, layout-only). Row:
    /// `None` — the bias belongs to the post-reduction graph, and adding
    /// a zero-filled slice instead would not be bit-neutral
    /// ((−0.0) + 0.0 = +0.0).
    bias: Option<Tensor>,
    /// First owned logical segment (row split; 0 for col).
    seg0: usize,
}

impl Linear {
    /// Freeze this shard's **output-column slice** into microkernel
    /// panels: shard `s` of `tp` owns output features
    /// `[s·n/tp, (s+1)·n/tp)` (weight rows in PyTorch layout) and the
    /// matching bias slice. Requires `out_features % tp == 0` (error,
    /// not a panic — serving-facing).
    pub fn pack_col_shard_in(&self, pool: &WorkerPool, plan: ShardPlan) -> Result<PackedLinearShard> {
        let (n, k) = (self.weight.dims()[0], self.weight.dims()[1]);
        if n % plan.tp != 0 {
            return Err(Error::shape(format!(
                "Linear col shard: out_features {n} not divisible by tp {}",
                plan.tp
            )));
        }
        let nl = n / plan.tp;
        let r0 = plan.shard * nl;
        // local Wᵀ (k, nl) from weight rows [r0, r0+nl) — layout only
        let wd = self.weight.data();
        let mut wt = vec![0.0f32; k * nl];
        for kk in 0..k {
            for c in 0..nl {
                wt[kk * nl + c] = wd[(r0 + c) * k + kk];
            }
        }
        let mut packed = vec![0.0f32; packed_b_len(k, nl)];
        pack_b_panels(pool, &wt, k, nl, &mut packed);
        let bias = Tensor::from_vec(&[nl], self.bias.data()[r0..r0 + nl].to_vec())?;
        Ok(PackedLinearShard {
            kind: SplitKind::Col,
            segs: vec![packed],
            seg_k: k,
            d_in: k,
            d_out: nl,
            bias: Some(bias),
            seg0: 0,
        })
    }

    /// Freeze this shard's **input-row (k) segments** into microkernel
    /// panels: k divides into [`TP_LOGICAL_PARTS`] equal contiguous
    /// logical segments, shard `s` owns segments
    /// `[s·parts/tp, (s+1)·parts/tp)` and packs one full-width panel set
    /// per segment. Requires `in_features % TP_LOGICAL_PARTS == 0`.
    pub fn pack_row_shard_in(&self, pool: &WorkerPool, plan: ShardPlan) -> Result<PackedLinearShard> {
        let (n, k) = (self.weight.dims()[0], self.weight.dims()[1]);
        if k % TP_LOGICAL_PARTS != 0 {
            return Err(Error::shape(format!(
                "Linear row shard: in_features {k} not divisible by the logical partial count {TP_LOGICAL_PARTS}"
            )));
        }
        let sk = k / TP_LOGICAL_PARTS;
        let (seg0, nsegs) = plan.owned_segments();
        let wd = self.weight.data();
        let mut segs = Vec::with_capacity(nsegs);
        let mut wt = vec![0.0f32; sk * n];
        for g in seg0..seg0 + nsegs {
            // segment g's Wᵀ block (sk, n): input columns [g·sk, (g+1)·sk)
            for kk in 0..sk {
                for c in 0..n {
                    wt[kk * n + c] = wd[c * k + g * sk + kk];
                }
            }
            let mut packed = vec![0.0f32; packed_b_len(sk, n)];
            pack_b_panels(pool, &wt, sk, n, &mut packed);
            segs.push(packed);
        }
        Ok(PackedLinearShard {
            kind: SplitKind::Row,
            segs,
            seg_k: sk,
            d_in: k,
            d_out: n,
            bias: None,
            seg0,
        })
    }
}

impl PackedLinearShard {
    /// Full input width of the unsharded layer.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output width of one GEMM on this shard (column-slice width for a
    /// col split, full output width for a row split).
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// First owned logical segment index (row split; 0 for col).
    pub fn seg0(&self) -> usize {
        self.seg0
    }

    /// Number of owned logical segments (row split; 1 for col).
    pub fn num_segs(&self) -> usize {
        self.segs.len()
    }

    /// Column-split forward: `x · (Wᵀ slice) + b slice` on (m, d_in)
    /// replicated input, returning this shard's (m, d_out) output-column
    /// slice. Concatenated over shards in shard order this is the
    /// unsharded output bit for bit (layout-only; asserted in tests).
    pub fn forward_col_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        if self.kind != SplitKind::Col {
            return Err(Error::shape("PackedLinearShard: row split has no column forward"));
        }
        let d = x.dims();
        if d.len() != 2 || d[1] != self.d_in {
            return Err(Error::shape(format!(
                "PackedLinearShard col: want (m, {}), got {d:?}",
                self.d_in
            )));
        }
        let (m, k, n) = (d[0], self.d_in, self.d_out);
        let bias = self.bias.as_ref().expect("col shard carries its bias slice");
        let b = bias.data();
        Ok(Tensor::filled_by(&[m, n], |buf| {
            gemm_packed_into(pool, x.data(), m, k, &self.segs[0], n, None, false, buf);
            // per-column bias — same graph as PackedLinear (one `+` per
            // element after the reduction)
            for row in buf.chunks_exact_mut(n) {
                for (v, bb) in row.iter_mut().zip(b.iter()) {
                    *v = *v + *bb;
                }
            }
        }))
    }

    /// Row-split forward: one bias-free (m, d_out) partial product per
    /// owned logical segment, in logical order. With `x_local` the input
    /// is this shard's own contiguous k-slice (width
    /// `num_segs · seg_k`, e.g. the upstream column shard's local
    /// output); otherwise it is the full replicated (m, d_in) activation
    /// and this shard reads its own segment columns. Either way each
    /// logical segment's GEMM consumes the identical input bits, so the
    /// partials — and the fixed-tree combination
    /// ([`reduce_row_partials`]) — are TP-invariant.
    pub fn forward_row_partials_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        x_local: bool,
    ) -> Result<Vec<Tensor>> {
        if self.kind != SplitKind::Row {
            return Err(Error::shape("PackedLinearShard: column split has no row partials"));
        }
        let d = x.dims();
        let want_w = if x_local { self.segs.len() * self.seg_k } else { self.d_in };
        if d.len() != 2 || d[1] != want_w {
            return Err(Error::shape(format!(
                "PackedLinearShard row: want (m, {want_w}), got {d:?}"
            )));
        }
        let (m, w) = (d[0], d[1]);
        let base = if x_local { 0 } else { self.seg0 * self.seg_k };
        let (sk, n) = (self.seg_k, self.d_out);
        let mut out = Vec::with_capacity(self.segs.len());
        let mut xs = vec![0.0f32; m * sk];
        for (j, seg) in self.segs.iter().enumerate() {
            let off = base + j * sk;
            for r in 0..m {
                xs[r * sk..(r + 1) * sk]
                    .copy_from_slice(&x.data()[r * w + off..r * w + off + sk]);
            }
            out.push(Tensor::filled_by(&[m, n], |buf| {
                gemm_packed_into(pool, &xs, m, sk, seg, n, None, false, buf);
            }));
        }
        Ok(out)
    }
}

/// Combine the [`TP_LOGICAL_PARTS`] row-split partials — collected from
/// the shards in logical segment order — through the fixed pairwise tree
/// ([`fixed_tree_reduce_into`]), then add the bias **exactly once**, one
/// `+` per element after the tree. This is the single reduction graph of
/// the sharded path; it is a pure function of the layer shape, so it is
/// identical at every tensor-parallel width (asserted in tests and
/// pinned against the Python emulator in `tests/golden_vectors.rs`).
pub fn reduce_row_partials(parts: &[Tensor], bias: &Tensor) -> Result<Tensor> {
    if parts.len() != TP_LOGICAL_PARTS {
        return Err(Error::shape(format!(
            "reduce_row_partials: want {TP_LOGICAL_PARTS} logical partials, got {}",
            parts.len()
        )));
    }
    let dims = parts[0].dims().to_vec();
    if dims.len() != 2 {
        return Err(Error::shape("reduce_row_partials: partials must be (m, n)"));
    }
    for p in parts {
        if p.dims() != &dims[..] {
            return Err(Error::shape("reduce_row_partials: ragged partials"));
        }
    }
    let n = dims[1];
    if bias.dims() != [n] {
        return Err(Error::shape(format!(
            "reduce_row_partials: bias {:?} does not match output width {n}",
            bias.dims()
        )));
    }
    let views: Vec<&[f32]> = parts.iter().map(|p| p.data()).collect();
    let b = bias.data();
    Ok(Tensor::filled_by(&dims, |buf| {
        fixed_tree_reduce_into(&views, buf);
        for row in buf.chunks_exact_mut(n) {
            for (v, bb) in row.iter_mut().zip(b.iter()) {
                *v = *v + *bb;
            }
        }
    }))
}

impl Module for Linear {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let w = t.param(self.weight.clone());
        let b = t.param(self.bias.clone());
        binds.push(w);
        binds.push(b);
        let wt = t.permute(w, &[1, 0])?; // (in, out)
        let y = t.matmul(x, wt)?;
        t.add_bias(y, b)
    }

    fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight, &mut self.bias]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let l = Linear::new(8, 4, 42);
        assert_eq!(l.weight.dims(), &[4, 8]);
        assert_eq!(l.bias.dims(), &[4]);
        assert_eq!(l.num_params(), 36);
        // same seed → same init bits
        let l2 = Linear::new(8, 4, 42);
        assert!(l.weight.bit_eq(&l2.weight));
        assert!(l.bias.bit_eq(&l2.bias));
    }

    #[test]
    fn forward_matches_manual_gemm() {
        let l = Linear::new(3, 2, 1);
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1.]).unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut binds = Vec::new();
        let y = l.forward(&mut t, xv, &mut binds).unwrap();
        assert_eq!(binds.len(), 2);
        let got = t.value(y);
        // manual: x · Wᵀ + b with the same kernels
        let wt = l.weight.transpose2d().unwrap();
        let want = crate::tensor::matmul(&x, &wt).unwrap().add_t(&l.bias).unwrap();
        assert!(got.bit_eq(&want));
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise() {
        let l = Linear::new(6, 5, 21);
        let x = Tensor::from_vec(&[3, 6], (0..18).map(|i| (i as f32 * 0.17).cos()).collect())
            .unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut binds = Vec::new();
        let want = t.value(l.forward(&mut t, xv, &mut binds).unwrap());
        for lanes in [1usize, 2, 4] {
            let pool = WorkerPool::new(lanes);
            let got = l.forward_infer_in(&pool, &x).unwrap();
            assert!(got.bit_eq(&want), "lanes={lanes}: off-tape forward changed bits");
        }
    }

    #[test]
    fn packed_forward_matches_unpacked_bitwise() {
        // shapes straddling the NR=16 panel boundary and m=1 (the KV
        // decode step shape) — the packed path must be indistinguishable
        for (d_in, d_out) in [(6usize, 5usize), (16, 16), (9, 33), (32, 17)] {
            let l = Linear::new(d_in, d_out, 77);
            for lanes in [1usize, 3] {
                let pool = WorkerPool::new(lanes);
                let p = l.pack_in(&pool).unwrap();
                assert_eq!((p.d_in(), p.d_out()), (d_in, d_out));
                for m in [1usize, 2, 9] {
                    let x = Tensor::from_vec(
                        &[m, d_in],
                        (0..m * d_in).map(|i| (i as f32 * 0.23).sin()).collect(),
                    )
                    .unwrap();
                    let want = l.forward_infer_in(&pool, &x).unwrap();
                    let got = p.forward_infer_in(&pool, &x).unwrap();
                    assert!(
                        got.bit_eq(&want),
                        "d_in={d_in} d_out={d_out} m={m} lanes={lanes}: packed changed bits"
                    );
                }
            }
        }
        // serving-facing shape errors, never panics
        let l = Linear::new(4, 3, 1);
        let pool = WorkerPool::new(1);
        let p = l.pack_in(&pool).unwrap();
        assert!(p.forward_infer_in(&pool, &Tensor::zeros(&[2, 5])).is_err());
        assert!(p.forward_infer_in(&pool, &Tensor::zeros(&[4])).is_err());
    }

    #[test]
    fn col_shards_concat_to_the_unsharded_bits() {
        // column split is layout-only: each output element keeps its
        // sequential-k graph, so shard outputs concatenated in shard
        // order must equal the unsharded packed forward bit for bit
        let l = Linear::new(6, 8, 5);
        let x = Tensor::from_vec(&[3, 6], (0..18).map(|i| (i as f32 * 0.19).sin()).collect())
            .unwrap();
        for lanes in [1usize, 2] {
            let pool = WorkerPool::new(lanes);
            let want = l.pack_in(&pool).unwrap().forward_infer_in(&pool, &x).unwrap();
            for tp in [1usize, 2, 4] {
                let nl = 8 / tp;
                let mut got = Tensor::zeros(&[3, 8]);
                for s in 0..tp {
                    let sh = l.pack_col_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap();
                    assert_eq!(sh.d_out(), nl);
                    let y = sh.forward_col_in(&pool, &x).unwrap();
                    for r in 0..3 {
                        got.data_mut()[r * 8 + s * nl..r * 8 + (s + 1) * nl]
                            .copy_from_slice(&y.data()[r * nl..(r + 1) * nl]);
                    }
                }
                assert!(got.bit_eq(&want), "tp={tp} lanes={lanes}: col shard changed bits");
            }
        }
    }

    #[test]
    fn row_split_bits_are_tp_invariant_and_match_the_explicit_tree() {
        let l = Linear::new(8, 5, 7);
        let x = Tensor::from_vec(&[3, 8], (0..24).map(|i| (i as f32 * 0.37).cos()).collect())
            .unwrap();
        let pool = WorkerPool::new(2);
        // independent reference: per-segment matmul_in partials through
        // the same fixed tree + one bias add
        let wt = l.weight.transpose2d().unwrap(); // (8, 5)
        let (m, k, sk, n) = (3usize, 8usize, 2usize, 5usize);
        let mut ref_parts = Vec::new();
        for g in 0..TP_LOGICAL_PARTS {
            let xs = Tensor::from_vec(
                &[m, sk],
                (0..m).flat_map(|r| x.data()[r * k + g * sk..r * k + (g + 1) * sk].to_vec()).collect(),
            )
            .unwrap();
            let ws = Tensor::from_vec(
                &[sk, n],
                (0..sk).flat_map(|kk| wt.data()[(g * sk + kk) * n..(g * sk + kk + 1) * n].to_vec()).collect(),
            )
            .unwrap();
            ref_parts.push(matmul_in(&pool, &xs, &ws).unwrap());
        }
        let want = reduce_row_partials(&ref_parts, &l.bias).unwrap();
        for tp in [1usize, 2, 4] {
            let mut parts = Vec::new();
            for s in 0..tp {
                let sh = l.pack_row_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap();
                assert_eq!(sh.num_segs(), TP_LOGICAL_PARTS / tp);
                assert_eq!(sh.seg0(), s * (TP_LOGICAL_PARTS / tp));
                parts.extend(sh.forward_row_partials_in(&pool, &x, false).unwrap());
            }
            let got = reduce_row_partials(&parts, &l.bias).unwrap();
            assert!(got.bit_eq(&want), "tp={tp}: row split changed bits");
        }
    }

    #[test]
    fn row_local_input_equals_replicated_input_bitwise() {
        // the Megatron chain: a shard consuming its upstream column
        // shard's local slice must see the identical segment bits it
        // would read out of the replicated activation
        let l = Linear::new(8, 5, 13);
        let x = Tensor::from_vec(&[2, 8], (0..16).map(|i| (i as f32 * 0.41).sin()).collect())
            .unwrap();
        let pool = WorkerPool::new(1);
        for (tp, s) in [(2usize, 1usize), (4, 2)] {
            let sh = l.pack_row_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap();
            let w_local = sh.num_segs() * 8 / TP_LOGICAL_PARTS;
            let off = sh.seg0() * (8 / TP_LOGICAL_PARTS);
            let xl = Tensor::from_vec(
                &[2, w_local],
                (0..2).flat_map(|r| x.data()[r * 8 + off..r * 8 + off + w_local].to_vec()).collect(),
            )
            .unwrap();
            let a = sh.forward_row_partials_in(&pool, &x, false).unwrap();
            let b = sh.forward_row_partials_in(&pool, &xl, true).unwrap();
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b.iter()) {
                assert!(pa.bit_eq(pb), "tp={tp} shard={s}: local-input partial changed bits");
            }
        }
    }

    #[test]
    fn shard_plans_and_indivisible_shapes_are_errors() {
        assert!(ShardPlan::new(0, 0).is_err(), "tp 0");
        assert!(ShardPlan::new(3, 0).is_err(), "3 does not divide TP_LOGICAL_PARTS");
        assert!(ShardPlan::new(8, 0).is_err(), "8 does not divide TP_LOGICAL_PARTS");
        assert!(ShardPlan::new(2, 2).is_err(), "shard ≥ tp");
        assert!(ShardPlan::new(4, 3).is_ok());
        let pool = WorkerPool::new(1);
        // indivisible widths are construction errors, never panics
        let l = Linear::new(6, 5, 1);
        assert!(l.pack_row_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).is_err(), "6 % 4");
        assert!(l.pack_col_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).is_err(), "5 % 2");
        // kind mismatches are shape errors
        let l = Linear::new(8, 8, 2);
        let col = l.pack_col_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        let row = l.pack_row_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        let x = Tensor::zeros(&[1, 8]);
        assert!(col.forward_row_partials_in(&pool, &x, false).is_err());
        assert!(row.forward_col_in(&pool, &x).is_err());
        // wrong partial count / ragged partials
        let parts = row.forward_row_partials_in(&pool, &x, false).unwrap();
        assert_eq!(parts.len(), 2);
        assert!(reduce_row_partials(&parts, &l.bias).is_err(), "2 of 4 partials");
    }

    #[test]
    fn gradient_flows_to_params() {
        let l = Linear::new(4, 3, 9);
        let x = Tensor::full(&[2, 4], 0.5);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let y = l.forward(&mut t, xv, &mut binds).unwrap();
        let loss = t.mean_all(y);
        t.backward(loss).unwrap();
        assert!(t.grad(binds[0]).is_some());
        assert_eq!(t.grad(binds[0]).unwrap().dims(), &[3, 4]);
        assert_eq!(t.grad(binds[1]).unwrap().dims(), &[3]);
    }
}
