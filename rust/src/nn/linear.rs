//! `nn::Linear` — y = x Wᵀ + b, PyTorch parameter layout (out, in).
//!
//! The GEMM is the RepDL sequential-k spec; the transpose is a layout
//! operation only (bit-neutral, see `tensor::matmul`).

use super::Module;
use crate::autograd::{Tape, Var};
use crate::rng::{derive_seed, kaiming_uniform, uniform_tensor};
use crate::rnum::rrsqrt;
use crate::tensor::{matmul_in, Tensor, WorkerPool};
use crate::Result;

/// Fully-connected layer.
pub struct Linear {
    /// Weight, shape (out_features, in_features) — PyTorch layout.
    pub weight: Tensor,
    /// Bias, shape (out_features,).
    pub bias: Tensor,
}

impl Linear {
    /// PyTorch-default init: Kaiming-uniform weight, U(−1/√in, 1/√in) bias.
    pub fn new(in_features: usize, out_features: usize, seed: u64) -> Self {
        let weight = kaiming_uniform(&[out_features, in_features], derive_seed(seed, 0));
        let bound = rrsqrt(in_features as f32);
        let bias = uniform_tensor(&[out_features], -bound, bound, derive_seed(seed, 1));
        Linear { weight, bias }
    }

    /// Off-tape inference forward on an explicit pool: `x Wᵀ + b` with no
    /// `Tape` node allocation. Same fixed graph as [`Module::forward`] —
    /// the transpose is layout-only and [`matmul_in`] computes the
    /// identical sequential-k unfused spec on any pool size — so the bits
    /// match the tape forward exactly (asserted in tests).
    ///
    /// The transpose is re-materialised per call because `weight` is
    /// mutable during training (`params_mut`) and this layer cannot know
    /// when it changes. Serving towers whose weights are frozen at
    /// construction could pack W once like `DeterministicServer` does —
    /// a ROADMAP follow-on, bit-neutral when it lands (layout only).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let wt = self.weight.transpose2d()?; // (in, out)
        matmul_in(pool, x, &wt)?.add_t(&self.bias)
    }
}

impl Module for Linear {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let w = t.param(self.weight.clone());
        let b = t.param(self.bias.clone());
        binds.push(w);
        binds.push(b);
        let wt = t.permute(w, &[1, 0])?; // (in, out)
        let y = t.matmul(x, wt)?;
        t.add_bias(y, b)
    }

    fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight, &mut self.bias]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let l = Linear::new(8, 4, 42);
        assert_eq!(l.weight.dims(), &[4, 8]);
        assert_eq!(l.bias.dims(), &[4]);
        assert_eq!(l.num_params(), 36);
        // same seed → same init bits
        let l2 = Linear::new(8, 4, 42);
        assert!(l.weight.bit_eq(&l2.weight));
        assert!(l.bias.bit_eq(&l2.bias));
    }

    #[test]
    fn forward_matches_manual_gemm() {
        let l = Linear::new(3, 2, 1);
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1.]).unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut binds = Vec::new();
        let y = l.forward(&mut t, xv, &mut binds).unwrap();
        assert_eq!(binds.len(), 2);
        let got = t.value(y);
        // manual: x · Wᵀ + b with the same kernels
        let wt = l.weight.transpose2d().unwrap();
        let want = crate::tensor::matmul(&x, &wt).unwrap().add_t(&l.bias).unwrap();
        assert!(got.bit_eq(&want));
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise() {
        let l = Linear::new(6, 5, 21);
        let x = Tensor::from_vec(&[3, 6], (0..18).map(|i| (i as f32 * 0.17).cos()).collect())
            .unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut binds = Vec::new();
        let want = t.value(l.forward(&mut t, xv, &mut binds).unwrap());
        for lanes in [1usize, 2, 4] {
            let pool = WorkerPool::new(lanes);
            let got = l.forward_infer_in(&pool, &x).unwrap();
            assert!(got.bit_eq(&want), "lanes={lanes}: off-tape forward changed bits");
        }
    }

    #[test]
    fn gradient_flows_to_params() {
        let l = Linear::new(4, 3, 9);
        let x = Tensor::full(&[2, 4], 0.5);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let y = l.forward(&mut t, xv, &mut binds).unwrap();
        let loss = t.mean_all(y);
        t.backward(loss).unwrap();
        assert!(t.grad(binds[0]).is_some());
        assert_eq!(t.grad(binds[0]).unwrap().dims(), &[3, 4]);
        assert_eq!(t.grad(binds[1]).unwrap().dims(), &[3]);
    }
}
