//! `nn::Linear` — y = x Wᵀ + b, PyTorch parameter layout (out, in).
//!
//! The GEMM is the RepDL sequential-k spec; the transpose is a layout
//! operation only (bit-neutral, see `tensor::matmul`).

use super::Module;
use crate::autograd::{Tape, Var};
use crate::rng::{derive_seed, kaiming_uniform, uniform_tensor};
use crate::rnum::rrsqrt;
use crate::tensor::microkernel::{gemm_packed_into, pack_b_panels, packed_b_len};
use crate::tensor::{matmul_in, Tensor, WorkerPool};
use crate::{Error, Result};

/// Fully-connected layer.
pub struct Linear {
    /// Weight, shape (out_features, in_features) — PyTorch layout.
    pub weight: Tensor,
    /// Bias, shape (out_features,).
    pub bias: Tensor,
}

impl Linear {
    /// PyTorch-default init: Kaiming-uniform weight, U(−1/√in, 1/√in) bias.
    pub fn new(in_features: usize, out_features: usize, seed: u64) -> Self {
        let weight = kaiming_uniform(&[out_features, in_features], derive_seed(seed, 0));
        let bound = rrsqrt(in_features as f32);
        let bias = uniform_tensor(&[out_features], -bound, bound, derive_seed(seed, 1));
        Linear { weight, bias }
    }

    /// Off-tape inference forward on an explicit pool: `x Wᵀ + b` with no
    /// `Tape` node allocation. Same fixed graph as [`Module::forward`] —
    /// the transpose is layout-only and [`matmul_in`] computes the
    /// identical sequential-k unfused spec on any pool size — so the bits
    /// match the tape forward exactly (asserted in tests).
    ///
    /// The transpose is re-materialised per call because `weight` is
    /// mutable during training (`params_mut`) and this layer cannot know
    /// when it changes. Serving towers whose weights are frozen at
    /// construction pack W once instead via [`Linear::pack_in`] —
    /// layout-only, bit-identical (asserted in tests).
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let wt = self.weight.transpose2d()?; // (in, out)
        matmul_in(pool, x, &wt)?.add_t(&self.bias)
    }

    /// Freeze this layer's weights into microkernel panels (the
    /// `DeterministicServer` trick): transpose **once**, pack **once**,
    /// and serve every subsequent request with zero per-call transpose
    /// or packing allocations. The snapshot is taken now — training this
    /// layer afterwards does not update the pack.
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedLinear> {
        let wt = self.weight.transpose2d()?; // (in, out), materialised once
        let (k, n) = (wt.dims()[0], wt.dims()[1]);
        let mut packed = vec![0.0f32; packed_b_len(k, n)];
        pack_b_panels(pool, wt.data(), k, n, &mut packed);
        Ok(PackedLinear { packed, bias: self.bias.clone(), d_in: k, d_out: n })
    }
}

/// A [`Linear`] frozen for serving: Wᵀ pre-packed into [`NR`-wide
/// microkernel panels](crate::tensor::microkernel) at construction.
///
/// Bit-neutrality: packing is layout-only, the packed GEMM keeps every
/// output element's sequential-k mul/add graph (`packed == blocked ==
/// dotform`, asserted in `tensor/microkernel.rs`), and the bias is added
/// per column with exactly one `+` per element after the reduction —
/// the identical graph `matmul_in(x, Wᵀ) + b` builds. So
/// [`PackedLinear::forward_infer_in`] ==
/// [`Linear::forward_infer_in`] bit for bit (asserted in tests), with
/// zero per-call transpose/pack allocations.
pub struct PackedLinear {
    packed: Vec<f32>,
    bias: Tensor,
    d_in: usize,
    d_out: usize,
}

impl PackedLinear {
    /// Input features.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output features.
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// `x Wᵀ + b` on (m, d_in) input through the pre-packed panels.
    pub fn forward_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let d = x.dims();
        if d.len() != 2 || d[1] != self.d_in {
            return Err(Error::shape(format!(
                "PackedLinear: want (m, {}), got {d:?}",
                self.d_in
            )));
        }
        let (m, k, n) = (d[0], self.d_in, self.d_out);
        let bias = self.bias.data();
        Ok(Tensor::filled_by(&[m, n], |buf| {
            gemm_packed_into(pool, x.data(), m, k, &self.packed, n, None, false, buf);
            // per-column bias, one add per element after the reduction —
            // the same graph as `add_t`'s (m,n)+(n,) broadcast
            for row in buf.chunks_exact_mut(n) {
                for (v, b) in row.iter_mut().zip(bias.iter()) {
                    *v = *v + *b;
                }
            }
        }))
    }
}

impl Module for Linear {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let w = t.param(self.weight.clone());
        let b = t.param(self.bias.clone());
        binds.push(w);
        binds.push(b);
        let wt = t.permute(w, &[1, 0])?; // (in, out)
        let y = t.matmul(x, wt)?;
        t.add_bias(y, b)
    }

    fn params(&self) -> Vec<&Tensor> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.weight, &mut self.bias]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let l = Linear::new(8, 4, 42);
        assert_eq!(l.weight.dims(), &[4, 8]);
        assert_eq!(l.bias.dims(), &[4]);
        assert_eq!(l.num_params(), 36);
        // same seed → same init bits
        let l2 = Linear::new(8, 4, 42);
        assert!(l.weight.bit_eq(&l2.weight));
        assert!(l.bias.bit_eq(&l2.bias));
    }

    #[test]
    fn forward_matches_manual_gemm() {
        let l = Linear::new(3, 2, 1);
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1.]).unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut binds = Vec::new();
        let y = l.forward(&mut t, xv, &mut binds).unwrap();
        assert_eq!(binds.len(), 2);
        let got = t.value(y);
        // manual: x · Wᵀ + b with the same kernels
        let wt = l.weight.transpose2d().unwrap();
        let want = crate::tensor::matmul(&x, &wt).unwrap().add_t(&l.bias).unwrap();
        assert!(got.bit_eq(&want));
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise() {
        let l = Linear::new(6, 5, 21);
        let x = Tensor::from_vec(&[3, 6], (0..18).map(|i| (i as f32 * 0.17).cos()).collect())
            .unwrap();
        let mut t = Tape::new();
        let xv = t.input(x.clone());
        let mut binds = Vec::new();
        let want = t.value(l.forward(&mut t, xv, &mut binds).unwrap());
        for lanes in [1usize, 2, 4] {
            let pool = WorkerPool::new(lanes);
            let got = l.forward_infer_in(&pool, &x).unwrap();
            assert!(got.bit_eq(&want), "lanes={lanes}: off-tape forward changed bits");
        }
    }

    #[test]
    fn packed_forward_matches_unpacked_bitwise() {
        // shapes straddling the NR=16 panel boundary and m=1 (the KV
        // decode step shape) — the packed path must be indistinguishable
        for (d_in, d_out) in [(6usize, 5usize), (16, 16), (9, 33), (32, 17)] {
            let l = Linear::new(d_in, d_out, 77);
            for lanes in [1usize, 3] {
                let pool = WorkerPool::new(lanes);
                let p = l.pack_in(&pool).unwrap();
                assert_eq!((p.d_in(), p.d_out()), (d_in, d_out));
                for m in [1usize, 2, 9] {
                    let x = Tensor::from_vec(
                        &[m, d_in],
                        (0..m * d_in).map(|i| (i as f32 * 0.23).sin()).collect(),
                    )
                    .unwrap();
                    let want = l.forward_infer_in(&pool, &x).unwrap();
                    let got = p.forward_infer_in(&pool, &x).unwrap();
                    assert!(
                        got.bit_eq(&want),
                        "d_in={d_in} d_out={d_out} m={m} lanes={lanes}: packed changed bits"
                    );
                }
            }
        }
        // serving-facing shape errors, never panics
        let l = Linear::new(4, 3, 1);
        let pool = WorkerPool::new(1);
        let p = l.pack_in(&pool).unwrap();
        assert!(p.forward_infer_in(&pool, &Tensor::zeros(&[2, 5])).is_err());
        assert!(p.forward_infer_in(&pool, &Tensor::zeros(&[4])).is_err());
    }

    #[test]
    fn gradient_flows_to_params() {
        let l = Linear::new(4, 3, 9);
        let x = Tensor::full(&[2, 4], 0.5);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let y = l.forward(&mut t, xv, &mut binds).unwrap();
        let loss = t.mean_all(y);
        t.backward(loss).unwrap();
        assert!(t.grad(binds[0]).is_some());
        assert_eq!(t.grad(binds[0]).unwrap().dims(), &[3, 4]);
        assert_eq!(t.grad(binds[1]).unwrap().dims(), &[3]);
    }
}
