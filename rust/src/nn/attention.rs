//! `nn::MultiheadAttention` — causal scaled-dot-product attention as one
//! fixed computation graph, with a hand-derived reproducible backward.
//!
//! Spec (per head, per batch): `S = QKᵀ·(1/√dh)` (unfused mul),
//! row-softmax with the `nn::softmax` fixed graph (first-max rule,
//! `rexp`, sequential sum), `O = P·V` with sequential-k dots. The causal
//! mask zeroes *logically* (masked scores never enter the reduction —
//! same skip rule as conv padding). Backward uses the standard closed
//! forms, every reduction sequential.

use super::linear::{reduce_row_partials, PackedLinearShard, ShardPlan, TP_LOGICAL_PARTS};
use super::Module;
use crate::autograd::{Tape, Var};
use crate::nn::{Linear, PackedLinear};
use crate::rnum::{rexp, rrsqrt};
use crate::tensor::{max_wins, Tensor, WorkerPool};
use crate::{Error, Result};

/// One attention query row — the per-(head, position) body shared
/// verbatim by the full forward ([`attention_forward`]) and the
/// incremental decode step ([`attention_step_forward`]), so the two
/// paths cannot drift apart bit-wise.
///
/// `kbase`/`vbase` address key/value row `j` at `j·row_stride ..
/// j·row_stride + Dh` — the full forward passes its contiguous
/// (T, Dh) head block (`row_stride = Dh`), the KV cache its time-major
/// (T, H, Dh) buffer offset to one head (`row_stride = H·Dh`). Strides
/// are layout; the value sequence each reduction consumes is identical.
///
/// `row` (length = the number of attended positions) receives the final
/// probabilities; `out_row` (length Dh) the attention output. Sequence:
/// unfused `q·k` scores scaled by `scale`, running max under the
/// canonical [`max_wins`] rule seeded `NEG_INFINITY`, `rexp` shift with
/// a **sequential** denominator sum, divide, then the sequential-j
/// `P·V` reduction per output element.
fn attention_row(
    q_row: &[f32],
    kbase: &[f32],
    vbase: &[f32],
    row_stride: usize,
    scale: f32,
    row: &mut [f32],
    out_row: &mut [f32],
) {
    let dh = q_row.len();
    let mut m = f32::NEG_INFINITY;
    for (j, r) in row.iter_mut().enumerate() {
        let krow = &kbase[j * row_stride..j * row_stride + dh];
        let mut acc = 0.0f32;
        for d in 0..dh {
            acc += q_row[d] * krow[d];
        }
        let s = acc * scale;
        *r = s;
        if max_wins(s, m) {
            m = s;
        }
    }
    let mut denom = 0.0f32;
    for r in row.iter_mut() {
        *r = rexp(*r - m);
        denom += *r;
    }
    for r in row.iter_mut() {
        *r = *r / denom;
    }
    for (d, o) in out_row.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (j, r) in row.iter().enumerate() {
            acc += *r * vbase[j * row_stride + d];
        }
        *o = acc;
    }
}

/// The attention forward spec on (BH, T, Dh) data, shared verbatim by
/// the tape op ([`attention_core`], which also needs the probabilities
/// for its backward) and the off-tape inference path
/// ([`MultiheadAttention::forward_seq_infer_in`]) — one implementation,
/// so the two paths cannot drift apart bit-wise.
///
/// Per (head, query) row: `S = QKᵀ·(1/√dh)` (unfused mul), running max
/// under the canonical [`max_wins`] rule (NaN wins, first occurrence —
/// DESIGN.md §8 migration; the NEG_INFINITY seed is exact: a -inf score
/// can only tie it, first occurrence keeps the seed's bits which equal
/// the score's, and a NaN score displaces it just as it would a real
/// max), `rexp` shift, **sequential** denominator sum, divide, then
/// `O = P·V` with sequential-j dots. The causal mask zeroes *logically*:
/// masked scores never enter any reduction.
///
/// Returns `(probs, out)` with `probs` shaped (BH, T, T) (masked slots
/// stay 0.0) and `out` shaped (BH, T, Dh). `want_probs = false` skips
/// materialising the (BH, T, T) tensor — only the tape backward needs
/// it, and the serving path should not pay an O(H·T²) allocation per
/// request for a value it discards. Bit-neutral: the P·V reduction
/// reads the identical stored f32 probabilities either way.
pub fn attention_forward(
    qv: &Tensor,
    kv: &Tensor,
    vv: &Tensor,
    causal: bool,
    want_probs: bool,
) -> Result<(Option<Tensor>, Tensor)> {
    let qd = qv.dims().to_vec();
    if qd.len() != 3 || kv.dims() != qd.as_slice() || vv.dims() != qd.as_slice() {
        return Err(Error::shape("attention_forward: want equal (BH,T,Dh)"));
    }
    let (bh, tt, dh) = (qd[0], qd[1], qd[2]);
    let scale = rrsqrt(dh as f32);
    let mut probs = want_probs.then(|| Tensor::zeros(&[bh, tt, tt]));
    let mut out = Tensor::zeros(&[bh, tt, dh]);
    for b in 0..bh {
        for i in 0..tt {
            let jmax = if causal { i + 1 } else { tt };
            let mut row = vec![0.0f32; jmax];
            let base = b * tt * dh;
            attention_row(
                &qv.data()[(b * tt + i) * dh..(b * tt + i + 1) * dh],
                &kv.data()[base..],
                &vv.data()[base..],
                dh,
                scale,
                &mut row,
                &mut out.data_mut()[(b * tt + i) * dh..(b * tt + i + 1) * dh],
            );
            if let Some(p) = probs.as_mut() {
                for (j, r) in row.iter().enumerate() {
                    p.data_mut()[(b * tt + i) * tt + j] = *r;
                }
            }
        }
    }
    Ok((probs, out))
}

/// Per-layer key/value cache for incremental (one-token-at-a-time)
/// decoding, stored **time-major**: step `j`, head `h` lives at
/// `(j·H + h)·Dh`. Appending a step is a contiguous copy; layout is
/// bit-irrelevant (the per-row reductions consume the same value
/// sequence the full forward's head-major blocks hold).
#[derive(Clone)]
pub struct KvState {
    k: Vec<f32>,
    v: Vec<f32>,
    heads: usize,
    dh: usize,
}

impl KvState {
    /// Empty cache for `heads` heads of width `dh`.
    pub fn new(heads: usize, dh: usize) -> Self {
        KvState { k: Vec::new(), v: Vec::new(), heads, dh }
    }

    /// Head count.
    pub fn heads(&self) -> usize {
        self.heads
    }

    /// Per-head width.
    pub fn head_dim(&self) -> usize {
        self.dh
    }

    /// Number of cached positions.
    pub fn steps(&self) -> usize {
        match self.heads * self.dh {
            0 => 0,
            w => self.k.len() / w,
        }
    }

    /// Append one position's keys and values, each `(H, Dh)` flattened
    /// head-major (= one contiguous `D`-row of the projected sequence).
    pub fn push_step(&mut self, k_step: &[f32], v_step: &[f32]) -> Result<()> {
        let w = self.heads * self.dh;
        if k_step.len() != w || v_step.len() != w {
            return Err(Error::shape(format!(
                "KvState::push_step: want two (H·Dh,) = ({w},) rows, got {} and {}",
                k_step.len(),
                v_step.len()
            )));
        }
        self.k.extend_from_slice(k_step);
        self.v.extend_from_slice(v_step);
        Ok(())
    }
}

/// Incremental attention: score ONE new query row `(H, Dh)` against all
/// cached key/value rows — which must already include the new
/// position's own K/V ([`KvState::push_step`] first), making the result
/// causal by construction (the query is the last row, so "attend to
/// everything cached" *is* the causal mask).
///
/// Each (head, row) runs the identical [`attention_row`] body the full
/// [`attention_forward`] runs for its last position, over the identical
/// value sequence — so incremental bits equal the full forward's
/// last-row bits by construction (asserted in tests and
/// `tests/serve_sessions.rs`).
pub fn attention_step_forward(q: &Tensor, kv: &KvState) -> Result<Tensor> {
    let d = q.dims();
    if d.len() != 2 || d[0] != kv.heads || d[1] != kv.dh {
        return Err(Error::shape(format!(
            "attention_step_forward: want ({}, {}) query, got {d:?}",
            kv.heads, kv.dh
        )));
    }
    let tt = kv.steps();
    if tt == 0 {
        return Err(Error::shape("attention_step_forward: empty KV cache"));
    }
    let (h, dh) = (kv.heads, kv.dh);
    let scale = rrsqrt(dh as f32);
    let mut out = Tensor::zeros(&[h, dh]);
    let mut row = vec![0.0f32; tt];
    for hh in 0..h {
        // every slot of `row` is overwritten per head, so reuse is safe
        attention_row(
            &q.data()[hh * dh..(hh + 1) * dh],
            &kv.k[hh * dh..],
            &kv.v[hh * dh..],
            h * dh,
            scale,
            &mut row,
            &mut out.data_mut()[hh * dh..(hh + 1) * dh],
        );
    }
    Ok(out)
}

/// Fused causal attention core on (BH, T, Dh) tensors.
/// Exposed for tests; models use [`MultiheadAttention`].
pub fn attention_core(t: &mut Tape, q: Var, k: Var, v: Var, causal: bool) -> Result<Var> {
    let qv = t.value(q);
    let kv = t.value(k);
    let vv = t.value(v);

    // forward (shared spec): validates the (BH,T,Dh) shapes — one copy
    // of the invariant — and saves the probabilities for backward
    let (probs, out) = attention_forward(&qv, &kv, &vv, causal, true)?;
    let probs = probs.expect("want_probs = true");
    let qd = qv.dims();
    let (bh, tt, dh) = (qd[0], qd[1], qd[2]);
    let scale = rrsqrt(dh as f32);

    let rg = true;
    let probs_saved = probs;
    Ok(t.push_custom(
        out,
        vec![q, k, v],
        Box::new(move |g, val| {
            let qv = val(q.index());
            let kv = val(k.index());
            let vv = val(v.index());
            let mut dq = Tensor::zeros(qv.dims());
            let mut dk = Tensor::zeros(kv.dims());
            let mut dv = Tensor::zeros(vv.dims());
            for b in 0..bh {
                for i in 0..tt {
                    let jmax = if causal { i + 1 } else { tt };
                    // dV[j] += P[i,j]·dO[i]; dP[i,j] = dO[i]·V[j]
                    let mut dp = vec![0.0f32; jmax];
                    for j in 0..jmax {
                        let p = probs_saved.data()[(b * tt + i) * tt + j];
                        let mut acc = 0.0f32;
                        for d in 0..dh {
                            let go = g.data()[(b * tt + i) * dh + d];
                            dv.data_mut()[(b * tt + j) * dh + d] += p * go;
                            acc += go * vv.data()[(b * tt + j) * dh + d];
                        }
                        dp[j] = acc;
                    }
                    // softmax backward: dS = P ∘ (dP − Σ_j dP·P)
                    let mut dot = 0.0f32;
                    for j in 0..jmax {
                        dot += dp[j] * probs_saved.data()[(b * tt + i) * tt + j];
                    }
                    for j in 0..jmax {
                        let p = probs_saved.data()[(b * tt + i) * tt + j];
                        let ds = p * (dp[j] - dot) * scale;
                        for d in 0..dh {
                            dq.data_mut()[(b * tt + i) * dh + d] +=
                                ds * kv.data()[(b * tt + j) * dh + d];
                            dk.data_mut()[(b * tt + j) * dh + d] +=
                                ds * qv.data()[(b * tt + i) * dh + d];
                        }
                    }
                }
            }
            vec![dq, dk, dv]
        }),
        rg,
    ))
}

/// Multi-head attention module (PyTorch naming).
pub struct MultiheadAttention {
    /// Fused QKV projection (3·D, D).
    pub in_proj: Linear,
    /// Output projection (D, D).
    pub out_proj: Linear,
    /// Head count.
    pub num_heads: usize,
    /// Causal masking.
    pub causal: bool,
}

impl MultiheadAttention {
    /// New module; `dim` must divide by `num_heads`.
    pub fn new(dim: usize, num_heads: usize, causal: bool, seed: u64) -> Result<Self> {
        if num_heads == 0 {
            // checked before the modulo: `dim % 0` is a panic, and a
            // degenerate config must be an error (serving-facing)
            return Err(Error::shape("MultiheadAttention: zero heads"));
        }
        if dim % num_heads != 0 {
            return Err(Error::shape("MultiheadAttention: dim % heads != 0"));
        }
        Ok(MultiheadAttention {
            in_proj: Linear::new(dim, 3 * dim, crate::rng::derive_seed(seed, 0)),
            out_proj: Linear::new(dim, dim, crate::rng::derive_seed(seed, 1)),
            num_heads,
            causal,
        })
    }

    /// Forward on a (T, D) sequence (single batch; callers loop batches
    /// or fold batch into BH).
    pub fn forward_seq(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let d = t.value_ref(x).dims().to_vec();
        if d.len() != 2 {
            return Err(Error::shape("MultiheadAttention: want (T, D)"));
        }
        let (tt, dim) = (d[0], d[1]);
        let h = self.num_heads;
        let dh = dim / h;
        let qkv = self.in_proj.forward(t, x, binds)?; // (T, 3D)
        // split into q,k,v: reshape (T, 3, H, Dh) → permute (3… ) — we
        // slice via fixed reshuffles: (T,3D) → (T,3,H,Dh) → (3,H,T,Dh)
        let r = t.reshape(qkv, &[tt, 3, h, dh])?;
        let p = t.permute(r, &[1, 2, 0, 3])?; // (3, H, T, Dh)
        let flat = t.reshape(p, &[3 * h * tt * dh])?;
        let q = t.slice(flat, 0, h * tt * dh)?;
        let k = t.slice(flat, h * tt * dh, h * tt * dh)?;
        let v = t.slice(flat, 2 * h * tt * dh, h * tt * dh)?;
        let q = t.reshape(q, &[h, tt, dh])?;
        let k = t.reshape(k, &[h, tt, dh])?;
        let v = t.reshape(v, &[h, tt, dh])?;
        let o = attention_core(t, q, k, v, self.causal)?; // (H,T,Dh)
        let o = t.permute(o, &[1, 0, 2])?; // (T,H,Dh)
        let o = t.reshape(o, &[tt, dim])?;
        self.out_proj.forward(t, o, binds)
    }

    /// Off-tape inference forward on a (T, D) sequence through an
    /// explicit pool: the QKV projection and output projection run as
    /// pooled GEMMs ([`super::Linear::forward_infer_in`]), the head
    /// split/merge shuffles are plain element copies (layout-only — the
    /// same `(T,3D) → (3,H,T,Dh)` and `(H,T,Dh) → (T,D)` index maps the
    /// tape path expresses as reshape/permute nodes), and the attention
    /// core is [`attention_forward`] — the *same function* the tape op
    /// calls. No tape node is allocated; bits match
    /// [`Self::forward_seq`] exactly (asserted in tests).
    pub fn forward_seq_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        self.forward_seq_packed_in(pool, x, None, None)
    }

    /// Freeze both projections into microkernel panels (layout-only;
    /// see [`PackedLinear`]).
    pub fn pack_in(&self, pool: &WorkerPool) -> Result<PackedAttention> {
        Ok(PackedAttention {
            in_proj: self.in_proj.pack_in(pool)?,
            out_proj: self.out_proj.pack_in(pool)?,
        })
    }

    /// [`Self::forward_seq_infer_in`] parameterized over the GEMM route
    /// and an optional KV capture — **one** orchestration implementation
    /// so the packed, unpacked, and cache-filling paths cannot drift.
    ///
    /// `packed`, when given, must be [`Self::pack_in`]'s output for this
    /// module; it changes only the GEMM applier (bit-neutral). `kv_out`,
    /// when given, must be empty; it receives every position's projected
    /// K/V rows — a pure layout copy of values this forward computes
    /// anyway, so prefill capture costs O(T·D) copies, not a recompute.
    pub fn forward_seq_packed_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        packed: Option<&PackedAttention>,
        kv_out: Option<&mut KvState>,
    ) -> Result<Tensor> {
        let d = x.dims();
        if d.len() != 2 {
            return Err(Error::shape("MultiheadAttention: want (T, D)"));
        }
        let (tt, dim) = (d[0], d[1]);
        let h = self.num_heads;
        let dh = dim / h;
        let qkv = match packed {
            Some(p) => p.in_proj.forward_infer_in(pool, x)?,
            None => self.in_proj.forward_infer_in(pool, x)?,
        }; // (T, 3D)
        // layout-only head split: q/k/v[h', t, d'] = qkv[t, c·D + h'·Dh + d']
        let mut q = Tensor::zeros(&[h, tt, dh]);
        let mut k = Tensor::zeros(&[h, tt, dh]);
        let mut v = Tensor::zeros(&[h, tt, dh]);
        for (c, dst) in [&mut q, &mut k, &mut v].into_iter().enumerate() {
            for hh in 0..h {
                for t in 0..tt {
                    let src = t * 3 * dim + c * dim + hh * dh;
                    dst.data_mut()[(hh * tt + t) * dh..(hh * tt + t + 1) * dh]
                        .copy_from_slice(&qkv.data()[src..src + dh]);
                }
            }
        }
        if let Some(kvs) = kv_out {
            if kvs.steps() != 0 || kvs.heads() != h || kvs.head_dim() != dh {
                return Err(Error::shape(
                    "MultiheadAttention: kv_out must be an empty cache of matching shape",
                ));
            }
            // prefill capture: each step's (H, Dh) K/V rows are exactly
            // one contiguous D-row of the projected sequence (head-major
            // in both layouts) — copied straight out of qkv
            for t in 0..tt {
                let kd = &qkv.data()[t * 3 * dim + dim..t * 3 * dim + 2 * dim];
                let vd = &qkv.data()[t * 3 * dim + 2 * dim..t * 3 * dim + 3 * dim];
                kvs.push_step(kd, vd)?;
            }
        }
        let (_, o) = attention_forward(&q, &k, &v, self.causal, false)?; // (H,T,Dh)
        // layout-only head merge: y[t, h'·Dh + d'] = o[h', t, d']
        let mut y = Tensor::zeros(&[tt, dim]);
        for hh in 0..h {
            for t in 0..tt {
                y.data_mut()[t * dim + hh * dh..t * dim + (hh + 1) * dh]
                    .copy_from_slice(&o.data()[(hh * tt + t) * dh..(hh * tt + t + 1) * dh]);
            }
        }
        match packed {
            Some(p) => p.out_proj.forward_infer_in(pool, &y),
            None => self.out_proj.forward_infer_in(pool, &y),
        }
    }

    /// Incremental decode: one new (1, D) position against the cached
    /// K/V rows. Appends this position's K/V to `kv`, then runs
    /// [`attention_step_forward`]. Bit-identical to the last row of
    /// [`Self::forward_seq_infer_in`] over the full prefix (the per-row
    /// graphs are position-independent; asserted in tests).
    pub fn forward_step_infer_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        kv: &mut KvState,
    ) -> Result<Tensor> {
        self.forward_step_packed_in(pool, x, kv, None)
    }

    /// [`Self::forward_step_infer_in`] parameterized over the GEMM
    /// route (same single-implementation rule as
    /// [`Self::forward_seq_packed_in`]).
    pub fn forward_step_packed_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        kv: &mut KvState,
        packed: Option<&PackedAttention>,
    ) -> Result<Tensor> {
        if !self.causal {
            // a step only equals the full forward's last row when "attend
            // to everything cached" IS the mask — i.e. causal attention
            return Err(Error::shape("MultiheadAttention step: causal attention only"));
        }
        let d = x.dims();
        if d.len() != 2 || d[0] != 1 {
            return Err(Error::shape("MultiheadAttention step: want (1, D)"));
        }
        let dim = d[1];
        let h = self.num_heads;
        let dh = dim / h;
        if kv.heads() != h || kv.head_dim() != dh {
            return Err(Error::shape("MultiheadAttention step: KV cache shape mismatch"));
        }
        let qkv = match packed {
            Some(p) => p.in_proj.forward_infer_in(pool, x)?,
            None => self.in_proj.forward_infer_in(pool, x)?,
        }; // (1, 3D)
        // for a single position the head-major (H, Dh) flattening IS the
        // contiguous D-slice — the split is the identity copy
        let qd = qkv.data()[..dim].to_vec();
        kv.push_step(&qkv.data()[dim..2 * dim], &qkv.data()[2 * dim..3 * dim])?;
        let q = Tensor::from_vec(&[h, dh], qd)?;
        let o = attention_step_forward(&q, kv)?; // (H, Dh)
        // head merge for one position is likewise the identity layout
        let y = o.reshape(&[1, dim])?;
        match packed {
            Some(p) => p.out_proj.forward_infer_in(pool, &y),
            None => self.out_proj.forward_infer_in(pool, &y),
        }
    }

    /// Freeze one tensor-parallel shard of this module: the QKV
    /// projection keeps only the rows feeding heads `[h_lo, h_hi)` — a
    /// gathered-row [`PackedLinear`]; layout-only, since each kept output
    /// element's full-k sequential dot is untouched — and the output
    /// projection is row-split over the head-concat dimension
    /// ([`Linear::pack_row_shard_in`]: this shard's head slice is exactly
    /// its owned logical segments). Requires `num_heads % tp == 0` and
    /// `dim % TP_LOGICAL_PARTS == 0` (errors, never panics).
    pub fn pack_shard_in(&self, pool: &WorkerPool, plan: ShardPlan) -> Result<PackedAttentionShard> {
        let dim = self.in_proj.weight.dims()[1];
        let h = self.num_heads;
        if h % plan.tp != 0 {
            return Err(Error::shape(format!(
                "MultiheadAttention shard: heads {h} not divisible by tp {}",
                plan.tp
            )));
        }
        let dh = dim / h;
        let hl = h / plan.tp;
        let (h_lo, h_hi) = (plan.shard * hl, (plan.shard + 1) * hl);
        let dl = hl * dh;
        // gather the q/k/v rows of this shard's heads into a (3·Dl, D)
        // projection — block order [q heads | k heads | v heads], the
        // same order the unsharded (3D, D) layout uses
        let wd = self.in_proj.weight.data();
        let bd = self.in_proj.bias.data();
        let mut w = vec![0.0f32; 3 * dl * dim];
        let mut b = vec![0.0f32; 3 * dl];
        for c in 0..3 {
            let src = c * dim + h_lo * dh;
            w[c * dl * dim..(c + 1) * dl * dim].copy_from_slice(&wd[src * dim..(src + dl) * dim]);
            b[c * dl..(c + 1) * dl].copy_from_slice(&bd[src..src + dl]);
        }
        let in_proj = Linear {
            weight: Tensor::from_vec(&[3 * dl, dim], w)?,
            bias: Tensor::from_vec(&[3 * dl], b)?,
        }
        .pack_in(pool)?;
        Ok(PackedAttentionShard {
            in_proj,
            out_proj: self.out_proj.pack_row_shard_in(pool, plan)?,
            h_lo,
            h_hi,
        })
    }

    /// Validate that `shards` is a complete, in-order head cover for this
    /// module; returns the per-shard head count.
    fn check_shards(&self, shards: &[&PackedAttentionShard], dim: usize) -> Result<usize> {
        let tp = shards.len();
        if tp == 0 || self.num_heads % tp != 0 {
            return Err(Error::shape(format!(
                "MultiheadAttention: {tp} shards cannot cover {} heads",
                self.num_heads
            )));
        }
        let hl = self.num_heads / tp;
        for (s, sh) in shards.iter().enumerate() {
            if sh.h_lo != s * hl || sh.h_hi != (s + 1) * hl || sh.in_proj.d_in() != dim {
                return Err(Error::shape(
                    "MultiheadAttention: shard set does not match this module's head plan",
                ));
            }
        }
        Ok(hl)
    }

    /// Tensor-parallel forward on a (T, D) sequence: each shard projects
    /// and attends only its own heads (layout-only — every head keeps
    /// its sequential score/softmax/mix graph, and heads concatenate in
    /// fixed head order), then emits its out-projection partials over
    /// its local head slice; the `TP_LOGICAL_PARTS` partials combine
    /// across shards in logical segment order through the fixed tree
    /// ([`reduce_row_partials`]). Bits are identical at every shard
    /// count dividing [`TP_LOGICAL_PARTS`] (asserted in tests and
    /// `tests/tp_invariance.rs`). `kv_out` capture fills the same
    /// full-layout cache the unsharded path fills, assembled across
    /// shards in fixed head order — so caches are interchangeable
    /// between TP widths.
    pub fn forward_seq_sharded_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        shards: &[&PackedAttentionShard],
        kv_out: Option<&mut KvState>,
    ) -> Result<Tensor> {
        let d = x.dims();
        if d.len() != 2 {
            return Err(Error::shape("MultiheadAttention: want (T, D)"));
        }
        let (tt, dim) = (d[0], d[1]);
        let h = self.num_heads;
        let dh = dim / h;
        let hl = self.check_shards(shards, dim)?;
        let dl = hl * dh;
        if let Some(kvs) = &kv_out {
            if kvs.steps() != 0 || kvs.heads() != h || kvs.head_dim() != dh {
                return Err(Error::shape(
                    "MultiheadAttention: kv_out must be an empty cache of matching shape",
                ));
            }
        }
        let capture = kv_out.is_some();
        let mut full_k = vec![0.0f32; if capture { tt * dim } else { 0 }];
        let mut full_v = vec![0.0f32; if capture { tt * dim } else { 0 }];
        let mut parts: Vec<Tensor> = Vec::with_capacity(TP_LOGICAL_PARTS);
        for sh in shards {
            let qkv = sh.in_proj.forward_infer_in(pool, x)?; // (T, 3·Dl)
            // layout-only local head split — the unsharded index map
            // restricted to this shard's heads
            let mut q = Tensor::zeros(&[hl, tt, dh]);
            let mut k = Tensor::zeros(&[hl, tt, dh]);
            let mut v = Tensor::zeros(&[hl, tt, dh]);
            for (c, dst) in [&mut q, &mut k, &mut v].into_iter().enumerate() {
                for hh in 0..hl {
                    for t in 0..tt {
                        let src = t * 3 * dl + c * dl + hh * dh;
                        dst.data_mut()[(hh * tt + t) * dh..(hh * tt + t + 1) * dh]
                            .copy_from_slice(&qkv.data()[src..src + dh]);
                    }
                }
            }
            if capture {
                for t in 0..tt {
                    let kd = &qkv.data()[t * 3 * dl + dl..t * 3 * dl + 2 * dl];
                    let vd = &qkv.data()[t * 3 * dl + 2 * dl..t * 3 * dl + 3 * dl];
                    let at = t * dim + sh.h_lo * dh;
                    full_k[at..at + dl].copy_from_slice(kd);
                    full_v[at..at + dl].copy_from_slice(vd);
                }
            }
            let (_, o) = attention_forward(&q, &k, &v, self.causal, false)?; // (hl,T,Dh)
            // this shard's local head-concat slice (T, Dl) — columns
            // [h_lo·Dh, h_hi·Dh) of the full merge, in fixed head order
            let mut y = Tensor::zeros(&[tt, dl]);
            for hh in 0..hl {
                for t in 0..tt {
                    y.data_mut()[t * dl + hh * dh..t * dl + (hh + 1) * dh]
                        .copy_from_slice(&o.data()[(hh * tt + t) * dh..(hh * tt + t + 1) * dh]);
                }
            }
            parts.extend(sh.out_proj.forward_row_partials_in(pool, &y, true)?);
        }
        if let Some(kvs) = kv_out {
            for t in 0..tt {
                kvs.push_step(&full_k[t * dim..(t + 1) * dim], &full_v[t * dim..(t + 1) * dim])?;
            }
        }
        reduce_row_partials(&parts, &self.out_proj.bias)
    }

    /// Tensor-parallel incremental decode: one new (1, D) position
    /// against the shared full-layout KV cache. Pass 1 projects every
    /// shard's heads and appends the assembled K/V step row **once**;
    /// pass 2 scores each shard's heads with the identical per-head
    /// [`attention_row`] body the unsharded step runs, then combines the
    /// out-projection partials through the fixed tree. Bit-identical to
    /// the last row of [`Self::forward_seq_sharded_in`] over the full
    /// prefix, and TP-invariant (asserted in tests).
    pub fn forward_step_sharded_in(
        &self,
        pool: &WorkerPool,
        x: &Tensor,
        shards: &[&PackedAttentionShard],
        kv: &mut KvState,
    ) -> Result<Tensor> {
        if !self.causal {
            return Err(Error::shape("MultiheadAttention step: causal attention only"));
        }
        let d = x.dims();
        if d.len() != 2 || d[0] != 1 {
            return Err(Error::shape("MultiheadAttention step: want (1, D)"));
        }
        let dim = d[1];
        let h = self.num_heads;
        let dh = dim / h;
        if kv.heads() != h || kv.head_dim() != dh {
            return Err(Error::shape("MultiheadAttention step: KV cache shape mismatch"));
        }
        let hl = self.check_shards(shards, dim)?;
        let dl = hl * dh;
        // pass 1: project, assemble the step's K/V rows in fixed head
        // order, append once
        let mut qs = Vec::with_capacity(shards.len());
        let mut k_full = vec![0.0f32; dim];
        let mut v_full = vec![0.0f32; dim];
        for sh in shards {
            let qkv = sh.in_proj.forward_infer_in(pool, x)?; // (1, 3·Dl)
            let at = sh.h_lo * dh;
            k_full[at..at + dl].copy_from_slice(&qkv.data()[dl..2 * dl]);
            v_full[at..at + dl].copy_from_slice(&qkv.data()[2 * dl..3 * dl]);
            qs.push(qkv);
        }
        kv.push_step(&k_full, &v_full)?;
        // pass 2: per-head attention over the shared cache + partials
        let tt = kv.steps();
        let scale = rrsqrt(dh as f32);
        let mut parts: Vec<Tensor> = Vec::with_capacity(TP_LOGICAL_PARTS);
        let mut row = vec![0.0f32; tt];
        for (s, sh) in shards.iter().enumerate() {
            let mut y = Tensor::zeros(&[1, dl]);
            for hh in 0..hl {
                let g = sh.h_lo + hh; // global head index
                attention_row(
                    &qs[s].data()[hh * dh..(hh + 1) * dh],
                    &kv.k[g * dh..],
                    &kv.v[g * dh..],
                    h * dh,
                    scale,
                    &mut row,
                    &mut y.data_mut()[hh * dh..(hh + 1) * dh],
                );
            }
            parts.extend(sh.out_proj.forward_row_partials_in(pool, &y, true)?);
        }
        reduce_row_partials(&parts, &self.out_proj.bias)
    }
}

/// A [`MultiheadAttention`] with both projections frozen into
/// microkernel panels ([`PackedLinear`]); built by
/// [`MultiheadAttention::pack_in`].
pub struct PackedAttention {
    /// Packed QKV projection.
    pub in_proj: PackedLinear,
    /// Packed output projection.
    pub out_proj: PackedLinear,
}

/// One tensor-parallel shard of a [`MultiheadAttention`]: the gathered
/// QKV rows of heads `[h_lo, h_hi)` plus the row-split output
/// projection whose owned logical segments are exactly this shard's
/// slice of the head-concat dimension. Built by
/// [`MultiheadAttention::pack_shard_in`]; driven by
/// [`MultiheadAttention::forward_seq_sharded_in`] /
/// [`MultiheadAttention::forward_step_sharded_in`].
pub struct PackedAttentionShard {
    in_proj: PackedLinear,
    out_proj: PackedLinearShard,
    h_lo: usize,
    h_hi: usize,
}

impl Module for MultiheadAttention {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        self.forward_seq(t, x, binds)
    }

    fn params(&self) -> Vec<&Tensor> {
        let mut p = self.in_proj.params();
        p.extend(self.out_proj.params());
        p
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.in_proj.params_mut();
        p.extend(self.out_proj.params_mut());
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(dims: &[usize], seed: u64) -> Tensor {
        let n: usize = dims.iter().product();
        let mut s = seed;
        Tensor::from_vec(
            dims,
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(31);
                    (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 0.6
                })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with causal mask, output row 0 == V row 0 exactly
        let q = lcg(&[1, 4, 8], 1);
        let k = lcg(&[1, 4, 8], 2);
        let v = lcg(&[1, 4, 8], 3);
        let mut t = Tape::new();
        let (qv, kv, vv) = (t.input(q), t.input(k), t.input(v.clone()));
        let o = attention_core(&mut t, qv, kv, vv, true).unwrap();
        let ov = t.value(o);
        for d in 0..8 {
            assert_eq!(ov.data()[d], v.data()[d], "row0 must equal V row0");
        }
    }

    #[test]
    fn attention_grads_match_finite_difference() {
        let q0 = lcg(&[2, 3, 4], 4);
        let k0 = lcg(&[2, 3, 4], 5);
        let v0 = lcg(&[2, 3, 4], 6);
        let run = |qq: &Tensor, kk: &Tensor, vvv: &Tensor| -> (f32, Tensor, Tensor, Tensor) {
            let mut t = Tape::new();
            let (q, k, v) = (t.param(qq.clone()), t.param(kk.clone()), t.param(vvv.clone()));
            let o = attention_core(&mut t, q, k, v, true).unwrap();
            let loss = t.mean_all(o);
            t.backward(loss).unwrap();
            (
                t.value(loss).data()[0],
                t.grad(q).unwrap(),
                t.grad(k).unwrap(),
                t.grad(v).unwrap(),
            )
        };
        let (_, gq, gk, gv) = run(&q0, &k0, &v0);
        let eps = 1e-3f32;
        for (which, base, grad) in [(0, &q0, &gq), (1, &k0, &gk), (2, &v0, &gv)] {
            for i in [0usize, 7, 23] {
                let mut p = base.clone();
                p.data_mut()[i] += eps;
                let mut m = base.clone();
                m.data_mut()[i] -= eps;
                let (lp, lm) = match which {
                    0 => (run(&p, &k0, &v0).0, run(&m, &k0, &v0).0),
                    1 => (run(&q0, &p, &v0).0, run(&q0, &m, &v0).0),
                    _ => (run(&q0, &k0, &p).0, run(&q0, &k0, &m).0),
                };
                let num = (lp - lm) / (2.0 * eps);
                let ana = grad.data()[i];
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                    "which={which} i={i}: num {num} vs ana {ana}"
                );
            }
        }
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise() {
        use crate::tensor::WorkerPool;
        // both causal and bidirectional, heads > 1 so the split/merge
        // index maps are actually exercised
        for causal in [true, false] {
            let mha = MultiheadAttention::new(12, 3, causal, 23).unwrap();
            let x = lcg(&[7, 12], 19);
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let want = t.value(mha.forward_seq(&mut t, xv, &mut b).unwrap());
            for lanes in [1usize, 3] {
                let pool = WorkerPool::new(lanes);
                let got = mha.forward_seq_infer_in(&pool, &x).unwrap();
                assert!(
                    got.bit_eq(&want),
                    "causal={causal} lanes={lanes}: off-tape attention changed bits"
                );
            }
        }
    }

    #[test]
    fn packed_seq_forward_matches_unpacked_bitwise() {
        use crate::tensor::WorkerPool;
        for causal in [true, false] {
            let mha = MultiheadAttention::new(12, 3, causal, 31).unwrap();
            let x = lcg(&[6, 12], 41);
            let pool = WorkerPool::new(2);
            let want = mha.forward_seq_infer_in(&pool, &x).unwrap();
            let packed = mha.pack_in(&pool).unwrap();
            let got = mha.forward_seq_packed_in(&pool, &x, Some(&packed), None).unwrap();
            assert!(got.bit_eq(&want), "causal={causal}: packed attention changed bits");
        }
    }

    #[test]
    fn step_decode_matches_full_forward_last_row_bitwise() {
        use crate::tensor::WorkerPool;
        let mha = MultiheadAttention::new(12, 3, true, 57).unwrap();
        let x = lcg(&[5, 12], 71);
        let pool = WorkerPool::new(2);
        let packed = mha.pack_in(&pool).unwrap();
        for use_packed in [false, true] {
            let p = use_packed.then_some(&packed);
            let mut kv = KvState::new(3, 4);
            for t in 0..5 {
                let row = Tensor::from_vec(&[1, 12], x.data()[t * 12..(t + 1) * 12].to_vec())
                    .unwrap();
                let step = mha.forward_step_packed_in(&pool, &row, &mut kv, p).unwrap();
                assert_eq!(kv.steps(), t + 1);
                // full forward over the prefix [0..=t]: its last row must
                // equal the incremental step exactly
                let prefix =
                    Tensor::from_vec(&[t + 1, 12], x.data()[..(t + 1) * 12].to_vec()).unwrap();
                let full = mha.forward_seq_infer_in(&pool, &prefix).unwrap();
                let last =
                    Tensor::from_vec(&[1, 12], full.data()[t * 12..(t + 1) * 12].to_vec())
                        .unwrap();
                assert!(
                    step.bit_eq(&last),
                    "packed={use_packed} t={t}: incremental decode changed bits"
                );
            }
        }
    }

    #[test]
    fn seq_forward_kv_capture_matches_step_built_cache() {
        use crate::tensor::WorkerPool;
        // prefill capture and step-built caches must hold identical bits
        let mha = MultiheadAttention::new(8, 2, true, 91).unwrap();
        let x = lcg(&[4, 8], 17);
        let pool = WorkerPool::new(1);
        let mut captured = KvState::new(2, 4);
        let _ = mha.forward_seq_packed_in(&pool, &x, None, Some(&mut captured)).unwrap();
        let mut stepped = KvState::new(2, 4);
        for t in 0..4 {
            let row = Tensor::from_vec(&[1, 8], x.data()[t * 8..(t + 1) * 8].to_vec()).unwrap();
            let _ = mha.forward_step_infer_in(&pool, &row, &mut stepped).unwrap();
        }
        assert_eq!(captured.steps(), 4);
        assert_eq!(stepped.steps(), 4);
        assert_eq!(
            captured.k.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            stepped.k.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            captured.v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            stepped.v.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn step_error_paths_never_panic() {
        use crate::tensor::WorkerPool;
        let pool = WorkerPool::new(1);
        // non-causal modules refuse to step
        let bidir = MultiheadAttention::new(8, 2, false, 3).unwrap();
        let mut kv = KvState::new(2, 4);
        let row = Tensor::zeros(&[1, 8]);
        assert!(bidir.forward_step_infer_in(&pool, &row, &mut kv).is_err());
        // shape mismatches are errors
        let mha = MultiheadAttention::new(8, 2, true, 3).unwrap();
        let mut wrong = KvState::new(4, 2);
        assert!(mha.forward_step_infer_in(&pool, &row, &mut wrong).is_err());
        assert!(mha
            .forward_step_infer_in(&pool, &Tensor::zeros(&[2, 8]), &mut kv)
            .is_err());
        // a non-empty kv_out is rejected at prefill
        let x = lcg(&[3, 8], 5);
        let mut used = KvState::new(2, 4);
        let _ = mha.forward_seq_packed_in(&pool, &x, None, Some(&mut used)).unwrap();
        assert!(mha.forward_seq_packed_in(&pool, &x, None, Some(&mut used)).is_err());
        // empty cache refuses to score
        let empty = KvState::new(2, 4);
        assert!(attention_step_forward(&Tensor::zeros(&[2, 4]), &empty).is_err());
    }

    #[test]
    fn sharded_seq_is_tp_invariant_and_kv_capture_is_layout_only() {
        use crate::tensor::WorkerPool;
        let mha = MultiheadAttention::new(8, 4, true, 101).unwrap();
        let x = lcg(&[5, 8], 9);
        let pool = WorkerPool::new(2);
        // the unsharded capture cache is the layout reference: head
        // split and QKV row gathering are layout-only, so every TP width
        // must fill the identical cache bits
        let mut kv_ref = KvState::new(4, 2);
        let _ = mha.forward_seq_packed_in(&pool, &x, None, Some(&mut kv_ref)).unwrap();
        let mut want: Option<Tensor> = None;
        for tp in [1usize, 2, 4] {
            let owned: Vec<_> = (0..tp)
                .map(|s| mha.pack_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap())
                .collect();
            let shards: Vec<&PackedAttentionShard> = owned.iter().collect();
            let mut kv = KvState::new(4, 2);
            let y = mha.forward_seq_sharded_in(&pool, &x, &shards, Some(&mut kv)).unwrap();
            assert_eq!(
                kv.k.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                kv_ref.k.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "tp={tp}: sharded K capture diverged from the unsharded cache"
            );
            assert_eq!(
                kv.v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                kv_ref.v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "tp={tp}: sharded V capture diverged from the unsharded cache"
            );
            match &want {
                None => want = Some(y),
                Some(w) => assert!(y.bit_eq(w), "tp={tp}: sharded attention changed bits"),
            }
        }
    }

    #[test]
    fn sharded_step_matches_sharded_seq_last_row_across_tp() {
        use crate::tensor::WorkerPool;
        let mha = MultiheadAttention::new(8, 4, true, 113).unwrap();
        let x = lcg(&[4, 8], 27);
        let pool = WorkerPool::new(1);
        let mut last_bits: Option<Vec<Vec<u32>>> = None;
        for tp in [1usize, 2, 4] {
            let owned: Vec<_> = (0..tp)
                .map(|s| mha.pack_shard_in(&pool, ShardPlan::new(tp, s).unwrap()).unwrap())
                .collect();
            let shards: Vec<&PackedAttentionShard> = owned.iter().collect();
            let mut kv = KvState::new(4, 2);
            let mut steps = Vec::new();
            for t in 0..4 {
                let row =
                    Tensor::from_vec(&[1, 8], x.data()[t * 8..(t + 1) * 8].to_vec()).unwrap();
                let step = mha.forward_step_sharded_in(&pool, &row, &shards, &mut kv).unwrap();
                assert_eq!(kv.steps(), t + 1);
                // the sharded step must equal the sharded full forward's
                // last row over the same prefix
                let prefix =
                    Tensor::from_vec(&[t + 1, 8], x.data()[..(t + 1) * 8].to_vec()).unwrap();
                let full = mha.forward_seq_sharded_in(&pool, &prefix, &shards, None).unwrap();
                let last = &full.data()[t * 8..(t + 1) * 8];
                assert_eq!(
                    step.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    last.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "tp={tp} t={t}: sharded step diverged from sharded seq"
                );
                steps.push(step.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>());
            }
            match &last_bits {
                None => last_bits = Some(steps),
                Some(w) => assert_eq!(w, &steps, "tp={tp}: sharded step bits not TP-invariant"),
            }
        }
    }

    #[test]
    fn shard_construction_and_mismatches_are_errors() {
        use crate::tensor::WorkerPool;
        let pool = WorkerPool::new(1);
        // heads not divisible by tp
        let mha2 = MultiheadAttention::new(8, 2, true, 1).unwrap();
        assert!(mha2.pack_shard_in(&pool, ShardPlan::new(4, 0).unwrap()).is_err());
        // dim not divisible by the logical partial count
        let mha6 = MultiheadAttention::new(6, 2, true, 1).unwrap();
        assert!(mha6.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).is_err());
        // incomplete / out-of-order shard sets are rejected at forward
        let mha = MultiheadAttention::new(8, 4, true, 1).unwrap();
        let s0 = mha.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).unwrap();
        let s1 = mha.pack_shard_in(&pool, ShardPlan::new(2, 1).unwrap()).unwrap();
        let x = lcg(&[3, 8], 2);
        assert!(mha.forward_seq_sharded_in(&pool, &x, &[&s1, &s0], None).is_err(), "order");
        assert!(mha.forward_seq_sharded_in(&pool, &x, &[&s0], None).is_err(), "incomplete");
        assert!(mha.forward_seq_sharded_in(&pool, &x, &[], None).is_err(), "empty");
        // non-causal modules refuse the sharded step too
        let bidir = MultiheadAttention::new(8, 4, false, 1).unwrap();
        let owned: Vec<_> = (0..2)
            .map(|s| bidir.pack_shard_in(&pool, ShardPlan::new(2, s).unwrap()).unwrap())
            .collect();
        let shards: Vec<&PackedAttentionShard> = owned.iter().collect();
        let mut kv = KvState::new(4, 2);
        let row = Tensor::zeros(&[1, 8]);
        assert!(bidir.forward_step_sharded_in(&pool, &row, &shards, &mut kv).is_err());
    }

    #[test]
    fn module_end_to_end_deterministic() {
        let mha = MultiheadAttention::new(8, 2, true, 11).unwrap();
        let x = lcg(&[5, 8], 7);
        let run = || {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let y = mha.forward_seq(&mut t, xv, &mut b).unwrap();
            let loss = t.mean_all(y);
            t.backward(loss).unwrap();
            let gs: Vec<Tensor> = b.iter().map(|v| t.grad(*v).unwrap()).collect();
            (t.value(loss), gs)
        };
        let (l1, g1) = run();
        let (l2, g2) = run();
        assert!(l1.bit_eq(&l2));
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!(a.bit_eq(b));
        }
    }
}
