//! `nn::MultiheadAttention` — causal scaled-dot-product attention as one
//! fixed computation graph, with a hand-derived reproducible backward.
//!
//! Spec (per head, per batch): `S = QKᵀ·(1/√dh)` (unfused mul),
//! row-softmax with the `nn::softmax` fixed graph (first-max rule,
//! `rexp`, sequential sum), `O = P·V` with sequential-k dots. The causal
//! mask zeroes *logically* (masked scores never enter the reduction —
//! same skip rule as conv padding). Backward uses the standard closed
//! forms, every reduction sequential.

use super::Module;
use crate::autograd::{Tape, Var};
use crate::nn::Linear;
use crate::rnum::{rexp, rrsqrt};
use crate::tensor::{max_wins, Tensor, WorkerPool};
use crate::{Error, Result};

/// The attention forward spec on (BH, T, Dh) data, shared verbatim by
/// the tape op ([`attention_core`], which also needs the probabilities
/// for its backward) and the off-tape inference path
/// ([`MultiheadAttention::forward_seq_infer_in`]) — one implementation,
/// so the two paths cannot drift apart bit-wise.
///
/// Per (head, query) row: `S = QKᵀ·(1/√dh)` (unfused mul), running max
/// under the canonical [`max_wins`] rule (NaN wins, first occurrence —
/// DESIGN.md §8 migration; the NEG_INFINITY seed is exact: a -inf score
/// can only tie it, first occurrence keeps the seed's bits which equal
/// the score's, and a NaN score displaces it just as it would a real
/// max), `rexp` shift, **sequential** denominator sum, divide, then
/// `O = P·V` with sequential-j dots. The causal mask zeroes *logically*:
/// masked scores never enter any reduction.
///
/// Returns `(probs, out)` with `probs` shaped (BH, T, T) (masked slots
/// stay 0.0) and `out` shaped (BH, T, Dh). `want_probs = false` skips
/// materialising the (BH, T, T) tensor — only the tape backward needs
/// it, and the serving path should not pay an O(H·T²) allocation per
/// request for a value it discards. Bit-neutral: the P·V reduction
/// reads the identical stored f32 probabilities either way.
pub fn attention_forward(
    qv: &Tensor,
    kv: &Tensor,
    vv: &Tensor,
    causal: bool,
    want_probs: bool,
) -> Result<(Option<Tensor>, Tensor)> {
    let qd = qv.dims().to_vec();
    if qd.len() != 3 || kv.dims() != qd.as_slice() || vv.dims() != qd.as_slice() {
        return Err(Error::shape("attention_forward: want equal (BH,T,Dh)"));
    }
    let (bh, tt, dh) = (qd[0], qd[1], qd[2]);
    let scale = rrsqrt(dh as f32);
    let mut probs = want_probs.then(|| Tensor::zeros(&[bh, tt, tt]));
    let mut out = Tensor::zeros(&[bh, tt, dh]);
    for b in 0..bh {
        for i in 0..tt {
            let jmax = if causal { i + 1 } else { tt };
            let mut row = vec![0.0f32; jmax];
            let mut m = f32::NEG_INFINITY;
            for (j, r) in row.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for d in 0..dh {
                    acc += qv.data()[(b * tt + i) * dh + d] * kv.data()[(b * tt + j) * dh + d];
                }
                let s = acc * scale;
                *r = s;
                if max_wins(s, m) {
                    m = s;
                }
            }
            let mut denom = 0.0f32;
            for r in row.iter_mut() {
                *r = rexp(*r - m);
                denom += *r;
            }
            for r in row.iter_mut() {
                *r = *r / denom;
            }
            if let Some(p) = probs.as_mut() {
                for (j, r) in row.iter().enumerate() {
                    p.data_mut()[(b * tt + i) * tt + j] = *r;
                }
            }
            for d in 0..dh {
                let mut acc = 0.0f32;
                for j in 0..jmax {
                    acc += row[j] * vv.data()[(b * tt + j) * dh + d];
                }
                out.data_mut()[(b * tt + i) * dh + d] = acc;
            }
        }
    }
    Ok((probs, out))
}

/// Fused causal attention core on (BH, T, Dh) tensors.
/// Exposed for tests; models use [`MultiheadAttention`].
pub fn attention_core(t: &mut Tape, q: Var, k: Var, v: Var, causal: bool) -> Result<Var> {
    let qv = t.value(q);
    let kv = t.value(k);
    let vv = t.value(v);

    // forward (shared spec): validates the (BH,T,Dh) shapes — one copy
    // of the invariant — and saves the probabilities for backward
    let (probs, out) = attention_forward(&qv, &kv, &vv, causal, true)?;
    let probs = probs.expect("want_probs = true");
    let qd = qv.dims();
    let (bh, tt, dh) = (qd[0], qd[1], qd[2]);
    let scale = rrsqrt(dh as f32);

    let rg = true;
    let probs_saved = probs;
    Ok(t.push_custom(
        out,
        vec![q, k, v],
        Box::new(move |g, val| {
            let qv = val(q.index());
            let kv = val(k.index());
            let vv = val(v.index());
            let mut dq = Tensor::zeros(qv.dims());
            let mut dk = Tensor::zeros(kv.dims());
            let mut dv = Tensor::zeros(vv.dims());
            for b in 0..bh {
                for i in 0..tt {
                    let jmax = if causal { i + 1 } else { tt };
                    // dV[j] += P[i,j]·dO[i]; dP[i,j] = dO[i]·V[j]
                    let mut dp = vec![0.0f32; jmax];
                    for j in 0..jmax {
                        let p = probs_saved.data()[(b * tt + i) * tt + j];
                        let mut acc = 0.0f32;
                        for d in 0..dh {
                            let go = g.data()[(b * tt + i) * dh + d];
                            dv.data_mut()[(b * tt + j) * dh + d] += p * go;
                            acc += go * vv.data()[(b * tt + j) * dh + d];
                        }
                        dp[j] = acc;
                    }
                    // softmax backward: dS = P ∘ (dP − Σ_j dP·P)
                    let mut dot = 0.0f32;
                    for j in 0..jmax {
                        dot += dp[j] * probs_saved.data()[(b * tt + i) * tt + j];
                    }
                    for j in 0..jmax {
                        let p = probs_saved.data()[(b * tt + i) * tt + j];
                        let ds = p * (dp[j] - dot) * scale;
                        for d in 0..dh {
                            dq.data_mut()[(b * tt + i) * dh + d] +=
                                ds * kv.data()[(b * tt + j) * dh + d];
                            dk.data_mut()[(b * tt + j) * dh + d] +=
                                ds * qv.data()[(b * tt + i) * dh + d];
                        }
                    }
                }
            }
            vec![dq, dk, dv]
        }),
        rg,
    ))
}

/// Multi-head attention module (PyTorch naming).
pub struct MultiheadAttention {
    /// Fused QKV projection (3·D, D).
    pub in_proj: Linear,
    /// Output projection (D, D).
    pub out_proj: Linear,
    /// Head count.
    pub num_heads: usize,
    /// Causal masking.
    pub causal: bool,
}

impl MultiheadAttention {
    /// New module; `dim` must divide by `num_heads`.
    pub fn new(dim: usize, num_heads: usize, causal: bool, seed: u64) -> Result<Self> {
        if num_heads == 0 {
            // checked before the modulo: `dim % 0` is a panic, and a
            // degenerate config must be an error (serving-facing)
            return Err(Error::shape("MultiheadAttention: zero heads"));
        }
        if dim % num_heads != 0 {
            return Err(Error::shape("MultiheadAttention: dim % heads != 0"));
        }
        Ok(MultiheadAttention {
            in_proj: Linear::new(dim, 3 * dim, crate::rng::derive_seed(seed, 0)),
            out_proj: Linear::new(dim, dim, crate::rng::derive_seed(seed, 1)),
            num_heads,
            causal,
        })
    }

    /// Forward on a (T, D) sequence (single batch; callers loop batches
    /// or fold batch into BH).
    pub fn forward_seq(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        let d = t.value_ref(x).dims().to_vec();
        if d.len() != 2 {
            return Err(Error::shape("MultiheadAttention: want (T, D)"));
        }
        let (tt, dim) = (d[0], d[1]);
        let h = self.num_heads;
        let dh = dim / h;
        let qkv = self.in_proj.forward(t, x, binds)?; // (T, 3D)
        // split into q,k,v: reshape (T, 3, H, Dh) → permute (3… ) — we
        // slice via fixed reshuffles: (T,3D) → (T,3,H,Dh) → (3,H,T,Dh)
        let r = t.reshape(qkv, &[tt, 3, h, dh])?;
        let p = t.permute(r, &[1, 2, 0, 3])?; // (3, H, T, Dh)
        let flat = t.reshape(p, &[3 * h * tt * dh])?;
        let q = t.slice(flat, 0, h * tt * dh)?;
        let k = t.slice(flat, h * tt * dh, h * tt * dh)?;
        let v = t.slice(flat, 2 * h * tt * dh, h * tt * dh)?;
        let q = t.reshape(q, &[h, tt, dh])?;
        let k = t.reshape(k, &[h, tt, dh])?;
        let v = t.reshape(v, &[h, tt, dh])?;
        let o = attention_core(t, q, k, v, self.causal)?; // (H,T,Dh)
        let o = t.permute(o, &[1, 0, 2])?; // (T,H,Dh)
        let o = t.reshape(o, &[tt, dim])?;
        self.out_proj.forward(t, o, binds)
    }

    /// Off-tape inference forward on a (T, D) sequence through an
    /// explicit pool: the QKV projection and output projection run as
    /// pooled GEMMs ([`super::Linear::forward_infer_in`]), the head
    /// split/merge shuffles are plain element copies (layout-only — the
    /// same `(T,3D) → (3,H,T,Dh)` and `(H,T,Dh) → (T,D)` index maps the
    /// tape path expresses as reshape/permute nodes), and the attention
    /// core is [`attention_forward`] — the *same function* the tape op
    /// calls. No tape node is allocated; bits match
    /// [`Self::forward_seq`] exactly (asserted in tests).
    pub fn forward_seq_infer_in(&self, pool: &WorkerPool, x: &Tensor) -> Result<Tensor> {
        let d = x.dims();
        if d.len() != 2 {
            return Err(Error::shape("MultiheadAttention: want (T, D)"));
        }
        let (tt, dim) = (d[0], d[1]);
        let h = self.num_heads;
        let dh = dim / h;
        let qkv = self.in_proj.forward_infer_in(pool, x)?; // (T, 3D)
        // layout-only head split: q/k/v[h', t, d'] = qkv[t, c·D + h'·Dh + d']
        let mut q = Tensor::zeros(&[h, tt, dh]);
        let mut k = Tensor::zeros(&[h, tt, dh]);
        let mut v = Tensor::zeros(&[h, tt, dh]);
        for (c, dst) in [&mut q, &mut k, &mut v].into_iter().enumerate() {
            for hh in 0..h {
                for t in 0..tt {
                    let src = t * 3 * dim + c * dim + hh * dh;
                    dst.data_mut()[(hh * tt + t) * dh..(hh * tt + t + 1) * dh]
                        .copy_from_slice(&qkv.data()[src..src + dh]);
                }
            }
        }
        let (_, o) = attention_forward(&q, &k, &v, self.causal, false)?; // (H,T,Dh)
        // layout-only head merge: y[t, h'·Dh + d'] = o[h', t, d']
        let mut y = Tensor::zeros(&[tt, dim]);
        for hh in 0..h {
            for t in 0..tt {
                y.data_mut()[t * dim + hh * dh..t * dim + (hh + 1) * dh]
                    .copy_from_slice(&o.data()[(hh * tt + t) * dh..(hh * tt + t + 1) * dh]);
            }
        }
        self.out_proj.forward_infer_in(pool, &y)
    }
}

impl Module for MultiheadAttention {
    fn forward(&self, t: &mut Tape, x: Var, binds: &mut Vec<Var>) -> Result<Var> {
        self.forward_seq(t, x, binds)
    }

    fn params(&self) -> Vec<&Tensor> {
        let mut p = self.in_proj.params();
        p.extend(self.out_proj.params());
        p
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.in_proj.params_mut();
        p.extend(self.out_proj.params_mut());
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(dims: &[usize], seed: u64) -> Tensor {
        let n: usize = dims.iter().product();
        let mut s = seed;
        Tensor::from_vec(
            dims,
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(31);
                    (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 0.6
                })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with causal mask, output row 0 == V row 0 exactly
        let q = lcg(&[1, 4, 8], 1);
        let k = lcg(&[1, 4, 8], 2);
        let v = lcg(&[1, 4, 8], 3);
        let mut t = Tape::new();
        let (qv, kv, vv) = (t.input(q), t.input(k), t.input(v.clone()));
        let o = attention_core(&mut t, qv, kv, vv, true).unwrap();
        let ov = t.value(o);
        for d in 0..8 {
            assert_eq!(ov.data()[d], v.data()[d], "row0 must equal V row0");
        }
    }

    #[test]
    fn attention_grads_match_finite_difference() {
        let q0 = lcg(&[2, 3, 4], 4);
        let k0 = lcg(&[2, 3, 4], 5);
        let v0 = lcg(&[2, 3, 4], 6);
        let run = |qq: &Tensor, kk: &Tensor, vvv: &Tensor| -> (f32, Tensor, Tensor, Tensor) {
            let mut t = Tape::new();
            let (q, k, v) = (t.param(qq.clone()), t.param(kk.clone()), t.param(vvv.clone()));
            let o = attention_core(&mut t, q, k, v, true).unwrap();
            let loss = t.mean_all(o);
            t.backward(loss).unwrap();
            (
                t.value(loss).data()[0],
                t.grad(q).unwrap(),
                t.grad(k).unwrap(),
                t.grad(v).unwrap(),
            )
        };
        let (_, gq, gk, gv) = run(&q0, &k0, &v0);
        let eps = 1e-3f32;
        for (which, base, grad) in [(0, &q0, &gq), (1, &k0, &gk), (2, &v0, &gv)] {
            for i in [0usize, 7, 23] {
                let mut p = base.clone();
                p.data_mut()[i] += eps;
                let mut m = base.clone();
                m.data_mut()[i] -= eps;
                let (lp, lm) = match which {
                    0 => (run(&p, &k0, &v0).0, run(&m, &k0, &v0).0),
                    1 => (run(&q0, &p, &v0).0, run(&q0, &m, &v0).0),
                    _ => (run(&q0, &k0, &p).0, run(&q0, &k0, &m).0),
                };
                let num = (lp - lm) / (2.0 * eps);
                let ana = grad.data()[i];
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                    "which={which} i={i}: num {num} vs ana {ana}"
                );
            }
        }
    }

    #[test]
    fn infer_forward_matches_tape_forward_bitwise() {
        use crate::tensor::WorkerPool;
        // both causal and bidirectional, heads > 1 so the split/merge
        // index maps are actually exercised
        for causal in [true, false] {
            let mha = MultiheadAttention::new(12, 3, causal, 23).unwrap();
            let x = lcg(&[7, 12], 19);
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let want = t.value(mha.forward_seq(&mut t, xv, &mut b).unwrap());
            for lanes in [1usize, 3] {
                let pool = WorkerPool::new(lanes);
                let got = mha.forward_seq_infer_in(&pool, &x).unwrap();
                assert!(
                    got.bit_eq(&want),
                    "causal={causal} lanes={lanes}: off-tape attention changed bits"
                );
            }
        }
    }

    #[test]
    fn module_end_to_end_deterministic() {
        let mha = MultiheadAttention::new(8, 2, true, 11).unwrap();
        let x = lcg(&[5, 8], 7);
        let run = || {
            let mut t = Tape::new();
            let xv = t.input(x.clone());
            let mut b = Vec::new();
            let y = mha.forward_seq(&mut t, xv, &mut b).unwrap();
            let loss = t.mean_all(y);
            t.backward(loss).unwrap();
            let gs: Vec<Tensor> = b.iter().map(|v| t.grad(*v).unwrap()).collect();
            (t.value(loss), gs)
        };
        let (l1, g1) = run();
        let (l2, g2) = run();
        assert!(l1.bit_eq(&l2));
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!(a.bit_eq(b));
        }
    }
}
