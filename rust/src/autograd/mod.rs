//! Tape-based reverse-mode autodiff with **deterministic gradient
//! accumulation**.
//!
//! The paper (§2.2.2) singles out atomic-add gradient accumulation as a
//! prime source of training non-determinism. This engine removes it
//! structurally: the tape replays in strict reverse creation order, and a
//! node's gradient contributions are added in that fixed order, so the
//! whole backward pass is one fixed computation graph. Every op's backward
//! is itself built from the reproducible `tensor`/`rnum` kernels.

pub mod tape;

pub use tape::{Tape, Var};
