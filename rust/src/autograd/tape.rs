//! The tape: an append-only arena of nodes, replayed in reverse.

use crate::rnum::special::{rgelu_tanh, rsigmoid, rtanh};
use crate::rnum::{rexp, rlog};
use crate::tensor::{matmul, max_pool2d_argmax, max_wins, sum_axis, Conv2dParams, Tensor};
use crate::{Error, Result};

/// Handle to a tape node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Var(usize);

impl Var {
    /// Raw tape index (for custom ops' backward closures).
    pub fn index(&self) -> usize {
        self.0
    }
}

enum Op {
    /// Leaf (input or parameter).
    Leaf,
    /// Generic op: parents + a backward that maps (grad_out, tape values)
    /// to one gradient per parent, in parent order.
    Node {
        parents: Vec<usize>,
        #[allow(clippy::type_complexity)]
        backward: Box<dyn Fn(&Tensor, &dyn Fn(usize) -> Tensor) -> Vec<Tensor>>,
    },
}

struct NodeRec {
    value: Tensor,
    grad: Option<Tensor>,
    op: Op,
    requires_grad: bool,
}

/// Reverse-mode tape. One tape per forward+backward pass.
#[derive(Default)]
pub struct Tape {
    nodes: Vec<NodeRec>,
}

impl Tape {
    /// Fresh empty tape.
    pub fn new() -> Self {
        Tape { nodes: Vec::new() }
    }

    fn push(&mut self, value: Tensor, op: Op, requires_grad: bool) -> Var {
        self.nodes.push(NodeRec { value, grad: None, op, requires_grad });
        Var(self.nodes.len() - 1)
    }

    /// Insert a constant input (no gradient tracked).
    pub fn input(&mut self, t: Tensor) -> Var {
        self.push(t, Op::Leaf, false)
    }

    /// Insert a parameter (gradient tracked).
    pub fn param(&mut self, t: Tensor) -> Var {
        self.push(t, Op::Leaf, true)
    }

    /// Value of a var (cloned).
    pub fn value(&self, v: Var) -> Tensor {
        self.nodes[v.0].value.clone()
    }

    /// Borrow the value of a var.
    pub fn value_ref(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].value
    }

    /// Gradient of a var after [`Tape::backward`] (None if not reached).
    pub fn grad(&self, v: Var) -> Option<Tensor> {
        self.nodes[v.0].grad.clone()
    }

    // -----------------------------------------------------------------
    // ops
    // -----------------------------------------------------------------

    /// Matrix product (2-D × 2-D).
    pub fn matmul(&mut self, a: Var, b: Var) -> Result<Var> {
        let v = matmul(self.value_ref(a), self.value_ref(b))?;
        let rg = self.req(a) || self.req(b);
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![a.0, b.0],
                backward: Box::new(move |g, val| {
                    let av = val(a.0);
                    let bv = val(b.0);
                    // dA = g · Bᵀ ; dB = Aᵀ · g (fixed graphs)
                    let da = matmul(g, &bv.transpose2d().unwrap()).unwrap();
                    let db = matmul(&av.transpose2d().unwrap(), g).unwrap();
                    vec![da, db]
                }),
            },
            rg,
        ))
    }

    /// Elementwise add (same shape).
    pub fn add(&mut self, a: Var, b: Var) -> Result<Var> {
        if self.value_ref(a).dims() != self.value_ref(b).dims() {
            return Err(Error::shape("tape add: shape mismatch"));
        }
        let v = self.value_ref(a).add_t(self.value_ref(b))?;
        let rg = self.req(a) || self.req(b);
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![a.0, b.0],
                backward: Box::new(|g, _| vec![g.clone(), g.clone()]),
            },
            rg,
        ))
    }

    /// Elementwise multiply (same shape).
    pub fn mul(&mut self, a: Var, b: Var) -> Result<Var> {
        if self.value_ref(a).dims() != self.value_ref(b).dims() {
            return Err(Error::shape("tape mul: shape mismatch"));
        }
        let v = self.value_ref(a).mul_t(self.value_ref(b))?;
        let rg = self.req(a) || self.req(b);
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![a.0, b.0],
                backward: Box::new(move |g, val| {
                    let av = val(a.0);
                    let bv = val(b.0);
                    vec![g.mul_t(&bv).unwrap(), g.mul_t(&av).unwrap()]
                }),
            },
            rg,
        ))
    }

    /// Add a length-N bias row to a (M,N) matrix.
    pub fn add_bias(&mut self, x: Var, b: Var) -> Result<Var> {
        let (xd, bd) = (self.value_ref(x).dims().to_vec(), self.value_ref(b).dims().to_vec());
        if xd.len() != 2 || bd != [xd[1]] {
            return Err(Error::shape("add_bias: want (M,N) + (N,)"));
        }
        let v = self.value_ref(x).add_t(self.value_ref(b))?;
        let rg = self.req(x) || self.req(b);
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![x.0, b.0],
                backward: Box::new(|g, _| {
                    // bias grad: sequential sum over rows (fixed order)
                    let db = sum_axis(g, 0).unwrap();
                    vec![g.clone(), db]
                }),
            },
            rg,
        ))
    }

    /// Multiply by a compile-time scalar.
    pub fn scale(&mut self, x: Var, s: f32) -> Var {
        let v = self.value_ref(x).mul_scalar(s);
        let rg = self.req(x);
        self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| vec![g.mul_scalar(s)]),
            },
            rg,
        )
    }

    /// ReLU.
    pub fn relu(&mut self, x: Var) -> Var {
        let xv = self.value_ref(x).clone();
        let v = xv.map(|t| if t > 0.0 { t } else { 0.0 });
        let rg = self.req(x);
        self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, val| {
                    let xv = val(x.0);
                    vec![g
                        .zip(&xv, |gg, t| if t > 0.0 { gg } else { 0.0 })
                        .unwrap()]
                }),
            },
            rg,
        )
    }

    /// GELU (tanh graph) with its fixed-graph derivative.
    pub fn gelu(&mut self, x: Var) -> Var {
        let v = self.value_ref(x).map(rgelu_tanh);
        let rg = self.req(x);
        self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, val| {
                    let xv = val(x.0);
                    // d/dx gelu_tanh: fixed graph
                    let dg = xv.map(|t| {
                        const S: f32 = 0.797_884_6;
                        const C: f32 = 0.044_715;
                        let u = S * (t + C * t * t * t);
                        let th = rtanh(u);
                        let sech2 = 1.0 - th * th;
                        0.5 * (1.0 + th) + 0.5 * t * sech2 * S * (1.0 + 3.0 * C * t * t)
                    });
                    vec![g.mul_t(&dg).unwrap()]
                }),
            },
            rg,
        )
    }

    /// tanh (correctly-rounded forward, fixed-graph derivative).
    pub fn tanh(&mut self, x: Var) -> Var {
        let v = self.value_ref(x).map(rtanh);
        let rg = self.req(x);
        let out = self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, val| {
                    let th = val(x.0).map(rtanh);
                    let d = th.map(|t| 1.0 - t * t);
                    vec![g.mul_t(&d).unwrap()]
                }),
            },
            rg,
        );
        out
    }

    /// Sigmoid (fixed graph), derivative σ(1−σ).
    pub fn sigmoid(&mut self, x: Var) -> Var {
        let v = self.value_ref(x).map(rsigmoid);
        let rg = self.req(x);
        self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, val| {
                    let s = val(x.0).map(rsigmoid);
                    let d = s.map(|t| t * (1.0 - t));
                    vec![g.mul_t(&d).unwrap()]
                }),
            },
            rg,
        )
    }

    /// Reshape (gradient reshapes back).
    pub fn reshape(&mut self, x: Var, dims: &[usize]) -> Result<Var> {
        let v = self.value_ref(x).reshape(dims)?;
        let rg = self.req(x);
        let old: Vec<usize> = self.value_ref(x).dims().to_vec();
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| vec![g.reshape(&old).unwrap()]),
            },
            rg,
        ))
    }

    /// Axis permutation (gradient applies the inverse permutation).
    pub fn permute(&mut self, x: Var, perm: &[usize]) -> Result<Var> {
        let v = self.value_ref(x).permute(perm)?;
        let rg = self.req(x);
        let mut inv = vec![0usize; perm.len()];
        for (i, &p) in perm.iter().enumerate() {
            inv[p] = i;
        }
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| vec![g.permute(&inv).unwrap()]),
            },
            rg,
        ))
    }

    /// Dropout with an externally-supplied 0/1 mask (the mask comes from
    /// the deterministic RNG; scaling by 1/keep is part of the graph).
    pub fn dropout(&mut self, x: Var, mask: &Tensor, keep: f32) -> Result<Var> {
        if mask.dims() != self.value_ref(x).dims() {
            return Err(Error::shape("dropout: mask shape mismatch"));
        }
        let inv = 1.0 / keep;
        let scaled_mask = mask.mul_scalar(inv);
        let v = self.value_ref(x).mul_t(&scaled_mask)?;
        let rg = self.req(x);
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| vec![g.mul_t(&scaled_mask).unwrap()]),
            },
            rg,
        ))
    }

    /// Row-stable softmax + cross-entropy against integer targets, fused
    /// (the fixed graph: max-shift → exp → sequential sum → log).
    /// Returns the scalar mean loss.
    pub fn softmax_cross_entropy(&mut self, logits: Var, targets: &[usize]) -> Result<Var> {
        let lv = self.value_ref(logits).clone();
        let d = lv.dims();
        if d.len() != 2 || targets.len() != d[0] {
            return Err(Error::shape("softmax_ce: want (B,C) logits + B targets"));
        }
        let (bsz, c) = (d[0], d[1]);
        let mut loss_acc = 0.0f32;
        let mut probs = Tensor::zeros(&[bsz, c]);
        for i in 0..bsz {
            let row = lv.row(i);
            // fixed graph: max (canonical max_wins rule — NaN wins, first
            // occurrence; DESIGN.md §8 migration), subtract, rexp, seq-sum
            let mut m = row[0];
            for &v in &row[1..] {
                if max_wins(v, m) {
                    m = v;
                }
            }
            let mut denom = 0.0f32;
            for j in 0..c {
                let e = rexp(row[j] - m);
                probs.data_mut()[i * c + j] = e;
                denom += e;
            }
            for j in 0..c {
                probs.data_mut()[i * c + j] /= denom;
            }
            // loss_i = −log p[target]
            loss_acc += -rlog(probs.data()[i * c + targets[i]]);
        }
        let loss = loss_acc / bsz as f32;
        let rg = self.req(logits);
        let targets: Vec<usize> = targets.to_vec();
        Ok(self.push(
            Tensor::scalar(loss),
            Op::Node {
                parents: vec![logits.0],
                backward: Box::new(move |g, _| {
                    // d logits = (softmax − onehot) / B · g
                    let gs = g.data()[0];
                    let mut dl = probs.clone();
                    for (i, &t) in targets.iter().enumerate() {
                        dl.data_mut()[i * c + t] -= 1.0;
                    }
                    vec![dl.map(|v| v / bsz as f32 * gs)]
                }),
            },
            rg,
        ))
    }

    /// LayerNorm over the last axis with affine params γ, β.
    /// Fixed graph: two-pass mean/var, rsqrt(var+ε) per row.
    pub fn layer_norm(&mut self, x: Var, gamma: Var, beta: Var, eps: f32) -> Result<Var> {
        let xv = self.value_ref(x).clone();
        let d = xv.dims().to_vec();
        let n = *d.last().ok_or_else(|| Error::shape("layer_norm: scalar input"))?;
        let gv = self.value_ref(gamma).clone();
        let bv = self.value_ref(beta).clone();
        if gv.dims() != [n] || bv.dims() != [n] {
            return Err(Error::shape("layer_norm: γ/β must match last axis"));
        }
        let rows = xv.numel() / n;
        let mut out = Tensor::zeros(&d);
        let mut xhat = Tensor::zeros(&d);
        let mut rstd = vec![0.0f32; rows];
        for r in 0..rows {
            let w = &xv.data()[r * n..(r + 1) * n];
            let mut s = 0.0f32;
            for &v in w {
                s += v;
            }
            let mu = s / n as f32;
            let mut v2 = 0.0f32;
            for &v in w {
                let dd = v - mu;
                v2 += dd * dd;
            }
            let var = v2 / n as f32;
            let rs = crate::rnum::rrsqrt(var + eps);
            rstd[r] = rs;
            for j in 0..n {
                let xh = (w[j] - mu) * rs;
                xhat.data_mut()[r * n + j] = xh;
                out.data_mut()[r * n + j] = xh * gv.data()[j] + bv.data()[j];
            }
        }
        let rg = self.req(x) || self.req(gamma) || self.req(beta);
        Ok(self.push(
            out,
            Op::Node {
                parents: vec![x.0, gamma.0, beta.0],
                backward: Box::new(move |g, val| {
                    let gv = val(gamma.0);
                    let nn = n as f32;
                    let mut dx = Tensor::zeros(xhat.dims());
                    let mut dgamma = Tensor::zeros(&[n]);
                    let mut dbeta = Tensor::zeros(&[n]);
                    for r in 0..rows {
                        // standard LN backward, fixed sequential sums
                        let mut sum_gy = 0.0f32;
                        let mut sum_gyx = 0.0f32;
                        for j in 0..n {
                            let gy = g.data()[r * n + j] * gv.data()[j];
                            sum_gy += gy;
                            sum_gyx += gy * xhat.data()[r * n + j];
                        }
                        for j in 0..n {
                            let gy = g.data()[r * n + j] * gv.data()[j];
                            let xh = xhat.data()[r * n + j];
                            dx.data_mut()[r * n + j] =
                                (gy - sum_gy / nn - xh * sum_gyx / nn) * rstd[r];
                        }
                    }
                    // parameter grads: sequential over rows (fixed order)
                    for j in 0..n {
                        let mut dgj = 0.0f32;
                        let mut dbj = 0.0f32;
                        for r in 0..rows {
                            dgj += g.data()[r * n + j] * xhat.data()[r * n + j];
                            dbj += g.data()[r * n + j];
                        }
                        dgamma.data_mut()[j] = dgj;
                        dbeta.data_mut()[j] = dbj;
                    }
                    vec![dx, dgamma, dbeta]
                }),
            },
            rg,
        ))
    }

    /// Embedding lookup: `ids` select rows of the `table` parameter.
    /// Backward is the paper's scatter-add hazard made deterministic:
    /// contributions accumulate **sequentially in token order**.
    pub fn embedding(&mut self, table: Var, ids: &[usize]) -> Result<Var> {
        let tv = self.value_ref(table).clone();
        let d = tv.dims();
        if d.len() != 2 {
            return Err(Error::shape("embedding: table must be (V,D)"));
        }
        let (vsz, dim) = (d[0], d[1]);
        for &i in ids {
            if i >= vsz {
                return Err(Error::shape(format!("embedding: id {i} ≥ vocab {vsz}")));
            }
        }
        let mut out = Tensor::zeros(&[ids.len(), dim]);
        for (r, &i) in ids.iter().enumerate() {
            out.data_mut()[r * dim..(r + 1) * dim].copy_from_slice(&tv.data()[i * dim..(i + 1) * dim]);
        }
        let rg = self.req(table);
        let ids: Vec<usize> = ids.to_vec();
        Ok(self.push(
            out,
            Op::Node {
                parents: vec![table.0],
                backward: Box::new(move |g, _| {
                    let mut dt = Tensor::zeros(&[vsz, dim]);
                    // deterministic scatter-add: token order
                    for (r, &i) in ids.iter().enumerate() {
                        for j in 0..dim {
                            dt.data_mut()[i * dim + j] += g.data()[r * dim + j];
                        }
                    }
                    vec![dt]
                }),
            },
            rg,
        ))
    }

    /// Max pooling (kernel = stride, valid padding) with a deterministic
    /// backward. Forward and argmax come from **one scan**
    /// ([`max_pool2d_argmax`], same seed + canonical [`max_wins`] order
    /// as the pooled `max_pool2d` kernel — NaN wins, first occurrence);
    /// backward scatters each output gradient to that recorded index, so
    /// the gradient flows to exactly the element whose bits the forward
    /// returned, NaN/tie windows included (NaN-rule unification
    /// migration, DESIGN.md §8). Windows are disjoint (kernel = stride),
    /// so the scatter is race-free.
    pub fn max_pool2d(&mut self, x: Var, k: usize) -> Result<Var> {
        let xv = self.value_ref(x);
        let (out, argmax) = max_pool2d_argmax(xv, k)?;
        let xd = xv.dims().to_vec();
        let n_in = xv.numel();
        let rg = self.req(x);
        Ok(self.push(
            out,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| {
                    let mut dx = Tensor::zeros(&[n_in]);
                    // disjoint windows: each input index wins at most once
                    for (e, &src) in argmax.iter().enumerate() {
                        dx.data_mut()[src] += g.data()[e];
                    }
                    vec![dx.reshape(&xd).unwrap()]
                }),
            },
            rg,
        ))
    }

    /// Reproducible conv2d (+ optional bias) with fixed-order backward.
    pub fn conv2d(
        &mut self,
        x: Var,
        w: Var,
        bias: Option<Var>,
        p: Conv2dParams,
    ) -> Result<Var> {
        let xv = self.value_ref(x).clone();
        let wv = self.value_ref(w).clone();
        let bv = bias.map(|b| self.value_ref(b).clone());
        let out = crate::tensor::conv2d(&xv, &wv, bv.as_ref(), p)?;
        let mut parents = vec![x.0, w.0];
        if let Some(b) = bias {
            parents.push(b.0);
        }
        let rg = self.req(x) || self.req(w) || bias.map(|b| self.req(b)).unwrap_or(false);
        let (xd, wd) = (xv.dims().to_vec(), wv.dims().to_vec());
        let od = out.dims().to_vec();
        Ok(self.push(
            out,
            Op::Node {
                parents,
                backward: Box::new(move |g, val| {
                    let xv = val(x.0);
                    let wv = val(w.0);
                    let (b, c, h, wid) = (xd[0], xd[1], xd[2], xd[3]);
                    let (o, kh, kw) = (wd[0], wd[2], wd[3]);
                    let (oh, ow) = (od[2], od[3]);
                    let mut dx = Tensor::zeros(&xd);
                    let mut dw = Tensor::zeros(&wd);
                    // fixed loop order: (b, o, oh, ow) outer, (c,kh,kw) inner
                    for bi in 0..b {
                        for oi in 0..o {
                            for ohh in 0..oh {
                                for oww in 0..ow {
                                    let gg = g.data()[((bi * o + oi) * oh + ohh) * ow + oww];
                                    if gg == 0.0 {
                                        continue;
                                    }
                                    for ci in 0..c {
                                        for khh in 0..kh {
                                            let ih = (ohh * p.stride + khh) as isize
                                                - p.padding as isize;
                                            if ih < 0 || ih >= h as isize {
                                                continue;
                                            }
                                            for kww in 0..kw {
                                                let iw = (oww * p.stride + kww) as isize
                                                    - p.padding as isize;
                                                if iw < 0 || iw >= wid as isize {
                                                    continue;
                                                }
                                                let xi = ((bi * c + ci) * h + ih as usize) * wid
                                                    + iw as usize;
                                                let wi = ((oi * c + ci) * kh + khh) * kw + kww;
                                                dx.data_mut()[xi] += gg * wv.data()[wi];
                                                dw.data_mut()[wi] += gg * xv.data()[xi];
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                    let mut grads = vec![dx, dw];
                    if bv.is_some() {
                        // bias grad: sum g over (b, oh, ow), sequential
                        let mut db = Tensor::zeros(&[o]);
                        for bi in 0..b {
                            for oi in 0..o {
                                let mut acc = db.data()[oi];
                                for s in 0..oh * ow {
                                    acc += g.data()[(bi * o + oi) * oh * ow + s];
                                }
                                db.data_mut()[oi] = acc;
                            }
                        }
                        grads.push(db);
                    }
                    grads
                }),
            },
            rg,
        ))
    }

    /// Register a custom op: precomputed value, parent vars, and a
    /// backward mapping (grad_out, value-lookup) → one grad per parent.
    /// Escape hatch for fused ops (attention) with hand-derived,
    /// fixed-order backwards.
    #[allow(clippy::type_complexity)]
    pub fn push_custom(
        &mut self,
        value: Tensor,
        parents: Vec<Var>,
        backward: Box<dyn Fn(&Tensor, &dyn Fn(usize) -> Tensor) -> Vec<Tensor>>,
        requires_grad: bool,
    ) -> Var {
        let parents = parents.into_iter().map(|v| v.0).collect();
        self.push(value, Op::Node { parents, backward }, requires_grad)
    }

    /// Contiguous 1-D slice of a flat tensor (backward zero-pads).
    pub fn slice(&mut self, x: Var, start: usize, len: usize) -> Result<Var> {
        let xv = self.value_ref(x);
        if xv.dims().len() != 1 || start + len > xv.numel() {
            return Err(Error::shape("slice: want flat tensor and valid range"));
        }
        let total = xv.numel();
        let v = Tensor::from_vec(&[len], xv.data()[start..start + len].to_vec())?;
        let rg = self.req(x);
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| {
                    let mut dx = Tensor::zeros(&[total]);
                    dx.data_mut()[start..start + len].copy_from_slice(g.data());
                    vec![dx]
                }),
            },
            rg,
        ))
    }

    /// Row slice of a 2-D tensor: rows [start, start+len).
    pub fn slice_rows(&mut self, x: Var, start: usize, len: usize) -> Result<Var> {
        let xv = self.value_ref(x);
        let d = xv.dims().to_vec();
        if d.len() != 2 || start + len > d[0] {
            return Err(Error::shape("slice_rows: want 2-D and valid range"));
        }
        let cols = d[1];
        let v = Tensor::from_vec(
            &[len, cols],
            xv.data()[start * cols..(start + len) * cols].to_vec(),
        )?;
        let rg = self.req(x);
        let rows = d[0];
        Ok(self.push(
            v,
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| {
                    let mut dx = Tensor::zeros(&[rows, cols]);
                    dx.data_mut()[start * cols..(start + len) * cols]
                        .copy_from_slice(g.data());
                    vec![dx]
                }),
            },
            rg,
        ))
    }

    /// Mean of all elements (fixed graph: sequential sum / n).
    pub fn mean_all(&mut self, x: Var) -> Var {
        let xv = self.value_ref(x);
        let n = xv.numel();
        let mut acc = 0.0f32;
        for &v in xv.data() {
            acc += v;
        }
        let rg = self.req(x);
        let dims = xv.dims().to_vec();
        self.push(
            Tensor::scalar(acc / n as f32),
            Op::Node {
                parents: vec![x.0],
                backward: Box::new(move |g, _| {
                    let gv = g.data()[0] / n as f32;
                    vec![Tensor::full(&dims, gv)]
                }),
            },
            rg,
        )
    }

    // -----------------------------------------------------------------
    // backward
    // -----------------------------------------------------------------

    fn req(&self, v: Var) -> bool {
        self.nodes[v.0].requires_grad
    }

    /// Run reverse-mode accumulation from a scalar loss var.
    /// Deterministic: fixed reverse order, fixed accumulation order.
    pub fn backward(&mut self, loss: Var) -> Result<()> {
        if self.nodes[loss.0].value.numel() != 1 {
            return Err(Error::shape("backward: loss must be scalar"));
        }
        // propagate requires_grad transitively (already done at op build).
        for n in self.nodes.iter_mut() {
            n.grad = None;
        }
        self.nodes[loss.0].grad = Some(Tensor::scalar(1.0));
        for i in (0..=loss.0).rev() {
            let g = match &self.nodes[i].grad {
                Some(g) => g.clone(),
                None => continue,
            };
            // take op pieces without holding a borrow on self.nodes
            let (parents, grads) = match &self.nodes[i].op {
                Op::Leaf => continue,
                Op::Node { parents, backward } => {
                    let values = |idx: usize| self.nodes[idx].value.clone();
                    let grads = backward(&g, &values);
                    (parents.clone(), grads)
                }
            };
            debug_assert_eq!(parents.len(), grads.len());
            for (p, pg) in parents.iter().zip(grads.into_iter()) {
                if !self.nodes[*p].requires_grad && !matches!(self.nodes[*p].op, Op::Node { .. })
                {
                    continue; // constant leaf: skip accumulation
                }
                let slot = &mut self.nodes[*p].grad;
                *slot = Some(match slot.take() {
                    None => pg,
                    Some(acc) => acc.add_t(&pg)?, // fixed accumulation order
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(dims: &[usize], seed: u64) -> Tensor {
        let n: usize = dims.iter().product();
        let mut s = seed;
        Tensor::from_vec(
            dims,
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(77);
                    (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 0.7
                })
                .collect(),
        )
        .unwrap()
    }

    /// Central-difference check of dL/dx[i] against the tape gradient.
    fn check_grad(
        build: impl Fn(&mut Tape, Var) -> Var,
        x0: &Tensor,
        tol: f32,
    ) {
        let mut tape = Tape::new();
        let x = tape.param(x0.clone());
        let loss = build(&mut tape, x);
        tape.backward(loss).unwrap();
        let g = tape.grad(x).unwrap();
        let eps = 1e-3f32;
        for i in (0..x0.numel()).step_by((x0.numel() / 7).max(1)) {
            let mut xp = x0.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x0.clone();
            xm.data_mut()[i] -= eps;
            let mut tp = Tape::new();
            let vp = tp.param(xp);
            let lp = build(&mut tp, vp);
            let mut tm = Tape::new();
            let vm = tm.param(xm);
            let lm = build(&mut tm, vm);
            let num = (tp.value_ref(lp).data()[0] - tm.value_ref(lm).data()[0]) / (2.0 * eps);
            let ana = g.data()[i];
            assert!(
                (num - ana).abs() <= tol * (1.0 + num.abs().max(ana.abs())),
                "grad[{i}]: numeric {num} vs tape {ana}"
            );
        }
    }

    #[test]
    fn matmul_grad_matches_finite_difference() {
        let x0 = lcg(&[4, 5], 1);
        let w = lcg(&[5, 3], 2);
        check_grad(
            |t, x| {
                let wv = t.input(w.clone());
                let y = t.matmul(x, wv).unwrap();
                t.mean_all(y)
            },
            &x0,
            1e-2,
        );
    }

    #[test]
    fn relu_tanh_gelu_sigmoid_grads() {
        let x0 = lcg(&[3, 7], 3);
        check_grad(|t, x| { let y = t.relu(x); t.mean_all(y) }, &x0, 1e-2);
        check_grad(|t, x| { let y = t.tanh(x); t.mean_all(y) }, &x0, 1e-2);
        check_grad(|t, x| { let y = t.gelu(x); t.mean_all(y) }, &x0, 2e-2);
        check_grad(|t, x| { let y = t.sigmoid(x); t.mean_all(y) }, &x0, 1e-2);
    }

    #[test]
    fn softmax_ce_grad() {
        let x0 = lcg(&[4, 6], 4);
        let targets = vec![1usize, 3, 0, 5];
        check_grad(
            |t, x| t.softmax_cross_entropy(x, &targets).unwrap(),
            &x0,
            2e-2,
        );
    }

    #[test]
    fn layer_norm_grad() {
        let x0 = lcg(&[3, 8], 5);
        let gamma = lcg(&[8], 6).map(|v| 1.0 + v);
        let beta = lcg(&[8], 7);
        check_grad(
            |t, x| {
                let g = t.param(gamma.clone());
                let b = t.param(beta.clone());
                let y = t.layer_norm(x, g, b, 1e-5).unwrap();
                t.mean_all(y)
            },
            &x0,
            2e-2,
        );
    }

    #[test]
    fn conv2d_grad() {
        let x0 = lcg(&[1, 2, 5, 5], 8);
        let w = lcg(&[3, 2, 3, 3], 9);
        check_grad(
            |t, x| {
                let wv = t.input(w.clone());
                let y = t
                    .conv2d(x, wv, None, Conv2dParams { stride: 1, padding: 1 })
                    .unwrap();
                t.mean_all(y)
            },
            &x0,
            2e-2,
        );
    }

    #[test]
    fn max_pool_grad_matches_finite_difference() {
        let x0 = lcg(&[1, 2, 4, 4], 15);
        check_grad(
            |t, x| {
                let y = t.max_pool2d(x, 2).unwrap();
                t.mean_all(y)
            },
            &x0,
            1e-2,
        );
    }

    #[test]
    fn max_pool_forward_backward_agree_on_nans_and_ties() {
        // one 4x4 plane, 2x2 windows chosen to exercise every rule case:
        //   window (0,0): NaN mid-window      → NaN wins, first occurrence
        //   window (0,1): exact tie           → first occurrence wins
        //   window (1,0): two NaNs, different payloads → FIRST payload kept
        //   window (1,1): plain finite max
        let nan_a = f32::from_bits(0x7fc0_0001);
        let nan_b = f32::from_bits(0x7fc0_0002);
        let x0 = Tensor::from_vec(
            &[1, 1, 4, 4],
            vec![
                1.0, 2.0, /* | */ 7.0, 5.0, //
                f32::NAN, 0.5, /* | */ 3.0, 7.0, //
                nan_a, 4.0, /* | */ -1.0, 6.0, //
                2.0, nan_b, /* | */ 0.0, 3.0,
            ],
        )
        .unwrap();
        let mut t = Tape::new();
        let x = t.param(x0.clone());
        let y = t.max_pool2d(x, 2).unwrap();
        let yv = t.value(y);
        // the forward agrees with max_axis over each flattened window
        // (shared max_wins rule), payload bits included
        let wins = [
            vec![1.0, 2.0, f32::NAN, 0.5],
            vec![7.0, 5.0, 3.0, 7.0],
            vec![nan_a, 4.0, 2.0, nan_b],
            vec![-1.0, 6.0, 0.0, 3.0],
        ];
        let want_idx = [2usize, 0, 0, 1]; // in-window argmax per max_wins
        for (wi, (win, &idx)) in wins.iter().zip(want_idx.iter()).enumerate() {
            let row = Tensor::from_vec(&[1, 4], win.clone()).unwrap();
            let m = crate::tensor::max_axis(&row, 1).unwrap().data()[0];
            assert_eq!(
                yv.data()[wi].to_bits(),
                m.to_bits(),
                "window {wi}: pooled max must equal max_axis bits"
            );
            assert_eq!(yv.data()[wi].to_bits(), win[idx].to_bits(), "window {wi}");
        }
        // backward: the gradient lands on exactly the element whose bits
        // the forward returned — one nonzero per window, at the max_wins
        // argmax (NaN windows included; ties go to the first occurrence)
        let loss = t.mean_all(y);
        t.backward(loss).unwrap();
        let g = t.grad(x).unwrap();
        let want_src = [4usize, 2, 8, 11]; // flat 4x4 indices per window
        let mut nonzero = Vec::new();
        for (i, &gv) in g.data().iter().enumerate() {
            if gv != 0.0 {
                assert_eq!(gv, 0.25, "uniform upstream grad");
                nonzero.push(i);
            }
        }
        assert_eq!(nonzero, want_src, "gradient must follow the max_wins argmax");
        for (&src, win_i) in want_src.iter().zip(0..4) {
            assert_eq!(
                x0.data()[src].to_bits(),
                yv.data()[win_i].to_bits(),
                "grad target must hold the forward output bits"
            );
        }
    }

    #[test]
    fn embedding_grad_is_deterministic_scatter() {
        let table = lcg(&[10, 4], 10);
        let ids = vec![3usize, 7, 3, 3, 1]; // repeated ids → accumulation
        let mut tape = Tape::new();
        let tb = tape.param(table.clone());
        let e = tape.embedding(tb, &ids).unwrap();
        let loss = tape.mean_all(e);
        tape.backward(loss).unwrap();
        let g1 = tape.grad(tb).unwrap();
        // repeat: bitwise identical
        let mut tape2 = Tape::new();
        let tb2 = tape2.param(table);
        let e2 = tape2.embedding(tb2, &ids).unwrap();
        let loss2 = tape2.mean_all(e2);
        tape2.backward(loss2).unwrap();
        assert!(g1.bit_eq(&tape2.grad(tb2).unwrap()));
        // row 3 got 3 contributions
        let per = 1.0 / (5.0 * 4.0);
        assert!((g1.data()[3 * 4] - 3.0 * per).abs() < 1e-6);
        assert!((g1.data()[7 * 4] - per).abs() < 1e-6);
        assert_eq!(g1.data()[0], 0.0);
    }

    #[test]
    fn fanout_accumulation_is_fixed_order() {
        // y = x·x (via mul with itself twice through different paths)
        let x0 = lcg(&[2, 2], 11);
        let mut tape = Tape::new();
        let x = tape.param(x0.clone());
        let a = tape.mul(x, x).unwrap();
        let b = tape.add(a, x).unwrap(); // x used 3 times in total
        let loss = tape.mean_all(b);
        tape.backward(loss).unwrap();
        let g = tape.grad(x).unwrap();
        // d/dx (x² + x) = 2x + 1, scaled by 1/4
        for i in 0..4 {
            let want = (2.0 * x0.data()[i] + 1.0) / 4.0;
            assert!((g.data()[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn whole_backward_is_bit_deterministic() {
        let x0 = lcg(&[4, 6], 12);
        let w0 = lcg(&[6, 6], 13);
        let run = || {
            let mut t = Tape::new();
            let x = t.param(x0.clone());
            let w = t.param(w0.clone());
            let h = t.matmul(x, w).unwrap();
            let h = t.gelu(h);
            let loss = t.softmax_cross_entropy(h, &[0, 1, 2, 3]).unwrap();
            t.backward(loss).unwrap();
            (t.grad(x).unwrap(), t.grad(w).unwrap(), t.value(loss))
        };
        let (gx1, gw1, l1) = run();
        let (gx2, gw2, l2) = run();
        assert!(gx1.bit_eq(&gx2));
        assert!(gw1.bit_eq(&gw2));
        assert!(l1.bit_eq(&l2));
    }

    #[test]
    fn dropout_masks_and_scales() {
        let x0 = Tensor::full(&[2, 2], 2.0);
        let mask = Tensor::from_vec(&[2, 2], vec![1., 0., 1., 1.]).unwrap();
        let mut t = Tape::new();
        let x = t.param(x0);
        let y = t.dropout(x, &mask, 0.75).unwrap();
        let v = t.value(y);
        assert!((v.data()[0] - 2.0 / 0.75).abs() < 1e-6);
        assert_eq!(v.data()[1], 0.0);
        let loss = t.mean_all(y);
        t.backward(loss).unwrap();
        let g = t.grad(x).unwrap();
        assert_eq!(g.data()[1], 0.0);
        assert!(g.data()[0] > 0.0);
    }

    #[test]
    fn permute_roundtrip_grad() {
        let x0 = lcg(&[2, 3, 4], 14);
        let mut t = Tape::new();
        let x = t.param(x0.clone());
        let p = t.permute(x, &[2, 0, 1]).unwrap();
        assert_eq!(t.value_ref(p).dims(), &[4, 2, 3]);
        let loss = t.mean_all(p);
        t.backward(loss).unwrap();
        let g = t.grad(x).unwrap();
        assert_eq!(g.dims(), &[2, 3, 4]);
        // mean over all: every element same grad
        assert!(g.data().iter().all(|&v| (v - 1.0 / 24.0).abs() < 1e-7));
    }
}
