//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from Rust — Python is never on this path.
//!
//! Interchange format is **HLO text** (see `python/compile/aot.py`):
//! jax ≥ 0.5 serialises protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids. Pattern
//! from /opt/xla-example/load_hlo.
//!
//! The manifest (`artifacts/manifest.json`) maps artifact names to files
//! and declared I/O shapes, so the coordinator can validate inputs before
//! touching PJRT.
//!
//! The PJRT backend needs the `xla` crate, which is not in the offline
//! crate set (DESIGN.md §5). It is therefore gated behind the `pjrt`
//! cargo feature (add the `xla` dependency to `Cargo.toml` when enabling
//! it). Without the feature this module still parses manifests
//! ([`load_manifest`]) but [`Runtime::new`] returns a clean error, and
//! the E6 cross-implementation tests self-skip.

#[cfg(feature = "pjrt")]
pub mod literal;

use crate::config::Json;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Declared shape of one artifact input/output.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// Dimensions.
    pub dims: Vec<usize>,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact name.
    pub name: String,
    /// HLO text file (relative to the artifacts dir).
    pub file: String,
    /// Input shapes in call order.
    pub inputs: Vec<IoSpec>,
    /// Output shapes in tuple order.
    pub outputs: Vec<IoSpec>,
}

/// Parse `manifest.json` in `dir` into artifact specs (backend-agnostic;
/// used by both the PJRT runtime and the stub).
pub fn load_manifest(dir: &Path) -> Result<HashMap<String, ArtifactSpec>> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        Error::runtime(format!(
            "cannot read {} (run `make artifacts` first): {e}",
            manifest_path.display()
        ))
    })?;
    let json = Json::parse(&text)?;
    let mut specs = HashMap::new();
    let arts = json
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::runtime("manifest missing 'artifacts' array"))?;
    for a in arts {
        let name = a.str_or("name", "").to_string();
        let file = a.str_or("file", "").to_string();
        let parse_io = |key: &str| -> Vec<IoSpec> {
            a.get(key)
                .and_then(Json::as_arr)
                .map(|xs| {
                    xs.iter()
                        .map(|s| IoSpec {
                            dims: s
                                .as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(Json::as_usize)
                                .collect(),
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let spec = ArtifactSpec {
            name: name.clone(),
            file,
            inputs: parse_io("inputs"),
            outputs: parse_io("outputs"),
        };
        specs.insert(name, spec);
    }
    Ok(specs)
}

/// PJRT CPU runtime with a compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Parsed manifest.
    pub specs: HashMap<String, ArtifactSpec>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open an artifacts directory (expects `manifest.json`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let specs = load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(format!("{e:?}")))?;
        Ok(Runtime { client, dir, specs, cache: HashMap::new() })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| Error::runtime(format!("unknown artifact '{name}'")))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::runtime("bad path"))?,
        )
        .map_err(|e| Error::Xla(format!("parse {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compile {name}: {e:?}")))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on the given inputs; returns the output tuple
    /// as tensors (shapes from the manifest).
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        let spec = self.specs.get(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::runtime(format!(
                "artifact '{name}': want {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
            if t.dims() != s.dims.as_slice() {
                return Err(Error::runtime(format!(
                    "artifact '{name}' input {i}: want {:?}, got {:?}",
                    s.dims,
                    t.dims()
                )));
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(literal::tensor_to_literal)
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| Error::Xla(format!("execute {name}: {e:?}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(format!("fetch {name}: {e:?}")))?;
        literal::tuple_to_tensors(lit, &spec.outputs)
    }
}

/// Stub runtime used when the crate is built without the `pjrt` feature:
/// construction fails with an actionable message, so every caller that
/// already handles "no artifacts" (the E6 tests, `repdl runtime`)
/// degrades to a clean skip.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    /// Parsed manifest (kept for API parity; never populated because
    /// `new` always errors).
    pub specs: HashMap<String, ArtifactSpec>,
    _dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always fails: the PJRT backend is compiled out.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        // Validate the manifest anyway so configuration errors surface
        // even in stub builds, then report the missing backend.
        let dir = dir.as_ref().to_path_buf();
        load_manifest(&dir)?;
        Err(Error::runtime(
            "PJRT backend not compiled in (build with `--features pjrt` and add the \
             `xla` dependency); run `make artifacts` first for the AOT files",
        ))
    }

    /// Stub platform name.
    pub fn platform(&self) -> String {
        "stub (no pjrt feature)".to_string()
    }

    /// Always fails in stub builds.
    pub fn load(&mut self, _name: &str) -> Result<()> {
        Err(Error::runtime("PJRT backend not compiled in"))
    }

    /// Always fails in stub builds.
    pub fn run(&mut self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(Error::runtime("PJRT backend not compiled in"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_a_clean_error() {
        let e = match Runtime::new("/nonexistent/path") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{e}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("repdl_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"name": "mm", "file": "mm.hlo.txt",
                 "inputs": [[2,3],[3,2]], "outputs": [[2,2]]}]}"#,
        )
        .unwrap();
        let specs = load_manifest(&dir).unwrap();
        let spec = &specs["mm"];
        assert_eq!(spec.inputs[0].dims, vec![2, 3]);
        assert_eq!(spec.outputs[0].dims, vec![2, 2]);
        #[cfg(feature = "pjrt")]
        {
            let rt = Runtime::new(&dir).unwrap();
            assert_eq!(rt.platform(), "cpu");
        }
        #[cfg(not(feature = "pjrt"))]
        {
            // stub builds refuse with an actionable message
            let msg = format!("{}", Runtime::new(&dir).unwrap_err());
            assert!(msg.contains("pjrt"), "{msg}");
        }
    }
}
