//! Tensor ↔ `xla::Literal` marshaling.

use super::IoSpec;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Dense f32 tensor → XLA literal (row-major, exact bit copy).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
    flat.reshape(&dims).map_err(|e| Error::Xla(format!("reshape literal: {e:?}")))
}

/// Output tuple literal → tensors, with shapes validated against the
/// manifest-declared specs.
pub fn tuple_to_tensors(lit: xla::Literal, outputs: &[IoSpec]) -> Result<Vec<Tensor>> {
    let parts = lit
        .to_tuple()
        .map_err(|e| Error::Xla(format!("untuple: {e:?}")))?;
    if parts.len() != outputs.len() {
        return Err(Error::runtime(format!(
            "artifact returned {} outputs, manifest declares {}",
            parts.len(),
            outputs.len()
        )));
    }
    parts
        .iter()
        .zip(outputs.iter())
        .map(|(p, spec)| {
            let data = p
                .to_vec::<f32>()
                .map_err(|e| Error::Xla(format!("literal to_vec: {e:?}")))?;
            Tensor::from_vec(&spec.dims, data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits_through_literal() {
        let t = Tensor::from_vec(&[2, 2], vec![1.5, -0.0, 3.25e-39, 7.0]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = lit.to_vec::<f32>().unwrap();
        for (a, b) in t.data().iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
