//! In-crate SHA-256 (FIPS 180-4) — the `sha2` crate is not in the
//! offline crate set (DESIGN.md §5), and the fingerprinting layer only
//! needs this one digest. The API mirrors the `Digest` subset the crate
//! uses: [`Sha256::new`], [`Sha256::update`], [`Sha256::finalize`].
//!
//! Known-answer tests cover the FIPS vectors plus multi-block and
//! incremental-update paths, so the fingerprints in
//! [`crate::coordinator::hashing`] and [`crate::tensor::Tensor::bit_hash`]
//! are stable, standard SHA-256 — the committed golden-vector fixtures
//! (`rust/tests/fixtures/`) depend on that.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5,
    0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc,
    0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3,
    0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5,
    0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 state.
pub struct Sha256 {
    h: [u32; 8],
    /// Partial input block (< 64 bytes buffered).
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes.
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh hash state.
    pub fn new() -> Self {
        Sha256 { h: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    /// Absorb bytes.
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Finish and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        // padding: 0x80, zeros to 56 mod 64, then the 64-bit length
        let mut pad = [0u8; 72];
        pad[0] = 0x80;
        let pad_len = 1 + ((55usize.wrapping_sub(self.total as usize)) % 64);
        pad[pad_len..pad_len + 8].copy_from_slice(&bit_len.to_be_bytes());
        self.update(&pad[..pad_len + 8]);
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, w) in self.h.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (t, wt) in w.iter_mut().take(16).enumerate() {
            *wt = u32::from_be_bytes(block[4 * t..4 * t + 4].try_into().unwrap());
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for t in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[t])
                .wrapping_add(w[t]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (hi, v) in self.h.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *hi = hi.wrapping_add(v);
        }
    }
}

/// One-shot digest.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(d: &[u8]) -> String {
        d.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a() {
        let mut h = Sha256::new();
        for _ in 0..1000 {
            h.update([b'a'; 1000]);
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data: Vec<u8> = (0..999u32).map(|i| (i * 7 % 256) as u8).collect();
        let oneshot = sha256(&data);
        // odd split points exercise every buffering path
        for split in [0, 1, 63, 64, 65, 500, 998, 999] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), oneshot, "split={split}");
        }
    }

    #[test]
    fn block_boundary_lengths() {
        // known digests for the exact padding corner cases (55/56/64 bytes)
        assert_eq!(
            hex(&sha256(&[0u8; 55])),
            "02779466cdec163811d078815c633f21901413081449002f24aa3e80f0b88ef7"
        );
        assert_eq!(
            hex(&sha256(&[0u8; 56])),
            "d4817aa5497628e7c77e6b606107042bbba3130888c5f47a375e6179be789fbb"
        );
        assert_eq!(
            hex(&sha256(&[0u8; 64])),
            "f5a5fd42d16a20302798ef6ed309979b43003d2320d9f0e8ea9831a92759fb4b"
        );
    }
}
