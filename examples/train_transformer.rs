//! E8 — the end-to-end driver: train a GPT-style char transformer on the
//! synthetic corpus for a few hundred steps, log the loss curve, then
//! prove bit-level reproducibility by (a) re-running and (b) comparing
//! state hashes — the paper's headline claim on a real training loop.
//!
//! ```sh
//! cargo run --release --offline --example train_transformer [steps]
//! ```

use repdl::autograd::Tape;
use repdl::coordinator::{compare_runs, hash_params};
use repdl::data::{BatchLoader, SyntheticCorpus};
use repdl::nn::{CharTransformer, TransformerConfig};
use repdl::optim::{cosine_lr, Adam};
use repdl::tensor::Tensor;
use std::time::Instant;

fn train(steps: usize, seed: u64, log: bool) -> (Vec<f32>, String) {
    let cfg = TransformerConfig {
        vocab: 28,
        dim: 48,
        heads: 4,
        layers: 2,
        context: 24,
        mlp_ratio: 2,
    };
    let corpus = SyntheticCorpus::generate(50_000, seed);
    let loader = BatchLoader::new(corpus.num_windows(cfg.context), 1, seed);
    let mut model = CharTransformer::new(cfg, seed).expect("model");
    let mut opt = Adam::new(0.0); // lr set per step by the schedule
    if log {
        println!("char-transformer: {} parameters", model.num_params());
        println!("corpus: {} tokens, vocab 28", corpus.tokens.len());
    }
    let order = loader.epoch_order(0);
    let mut curve = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for step in 0..steps {
        let pos = order[step % order.len()];
        let ids: Vec<usize> = corpus.window(pos, cfg.context).to_vec();
        let mut tape = Tape::new();
        let mut binds = Vec::new();
        let loss = model.loss_on_sequence(&mut tape, &ids, &mut binds).expect("fwd");
        tape.backward(loss).expect("bwd");
        let grads: Vec<Tensor> = binds.iter().map(|v| tape.grad(*v).unwrap()).collect();
        opt.lr = cosine_lr(step as u32, 20, steps as u32, 6e-3, 5e-4);
        opt.step(model.params_mut(), &grads).expect("opt");
        let lv = tape.value(loss).data()[0];
        curve.push(lv);
        if log && (step % 25 == 0 || step + 1 == steps) {
            let avg: f32 = curve[curve.len().saturating_sub(20)..].iter().sum::<f32>()
                / curve[curve.len().saturating_sub(20)..].len() as f32;
            println!(
                "step {step:>4}  loss {lv:.4}  (avg20 {avg:.4})  lr {:.5}  [{:.1}s]",
                opt.lr,
                t0.elapsed().as_secs_f32()
            );
        }
    }
    let params = model.params_mut();
    let refs: Vec<&Tensor> = params.iter().map(|p| &**p).collect();
    (curve, hash_params(&refs))
}

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    println!("=== run A ===");
    let (curve_a, hash_a) = train(steps, 7, true);

    println!("\n=== run B (identical config) ===");
    let (curve_b, hash_b) = train(steps, 7, false);
    let c = compare_runs(&curve_a, &curve_b, &hash_a, &hash_b);
    println!("loss curves bitwise identical : {}", c.curves_identical);
    println!("final param hashes equal      : {}", c.hashes_equal);
    println!("hash A {}", &hash_a[..32]);
    println!("hash B {}", &hash_b[..32]);

    // headline numbers
    let first: f32 = curve_a[..10.min(curve_a.len())].iter().sum::<f32>() / 10f32.min(curve_a.len() as f32);
    let last: f32 = curve_a[curve_a.len().saturating_sub(10)..].iter().sum::<f32>() / 10.0;
    println!("\nloss: {first:.4} -> {last:.4} over {steps} steps (uniform = ln 28 = 3.33)");
    assert!(c.curves_identical && c.hashes_equal, "REPRODUCIBILITY VIOLATION");
    println!("E8: PASS — end-to-end training is bit-level reproducible");
}
