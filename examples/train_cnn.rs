//! CNN image-classification training — the paper's §3.2.2 convolution
//! workload end to end: Conv2d → ReLU → Conv2d → ReLU → flatten → Linear,
//! trained on the deterministic Gaussian-blob dataset, with the usual
//! run-twice bitwise verification.
//!
//! ```sh
//! cargo run --release --offline --example train_cnn [steps]
//! ```

use repdl::autograd::Tape;
use repdl::coordinator::hash_params;
use repdl::data::GaussianMixtureImages;
use repdl::nn::{Conv2d, Linear, Module};
use repdl::optim::SGD;
use repdl::tensor::{Conv2dParams, Tensor};

struct Cnn {
    c1: Conv2d,
    c2: Conv2d,
    fc: Linear,
}

impl Cnn {
    fn new(seed: u64) -> Self {
        let p = Conv2dParams { stride: 1, padding: 1 };
        Cnn {
            c1: Conv2d::new(1, 8, 3, p, seed),
            c2: Conv2d::new(8, 8, 3, p, seed + 1),
            fc: Linear::new(8 * 8 * 8, 4, seed + 2),
        }
    }

    fn forward(&self, t: &mut Tape, x: repdl::autograd::Var, binds: &mut Vec<repdl::autograd::Var>) -> repdl::autograd::Var {
        let b = t.value_ref(x).dims()[0];
        let h = self.c1.forward(t, x, binds).unwrap();
        let h = t.relu(h);
        let h = self.c2.forward(t, h, binds).unwrap();
        let h = t.relu(h);
        let h = t.reshape(h, &[b, 8 * 8 * 8]).unwrap();
        self.fc.forward(t, h, binds).unwrap()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.c1.params_mut();
        p.extend(self.c2.params_mut());
        p.extend(self.fc.params_mut());
        p
    }
}

fn run(steps: usize, log: bool) -> (f32, f32, String) {
    let ds = GaussianMixtureImages::new(8, 4, 4096, 11);
    let mut model = Cnn::new(3);
    let mut opt = SGD::new(0.05, 0.9, 0.0);
    let (mut first_acc, mut last_acc) = (0.0f32, 0.0f32);
    for step in 0..steps {
        let idxs: Vec<usize> = (0..16).map(|i| (step * 16 + i) % 4096).collect();
        let (x, labels) = ds.batch(&idxs);
        let mut t = Tape::new();
        let xv = t.input(x);
        let mut binds = Vec::new();
        let logits = model.forward(&mut t, xv, &mut binds);
        let loss = t.softmax_cross_entropy(logits, &labels).unwrap();
        t.backward(loss).unwrap();
        // accuracy for the log
        let preds = repdl::tensor::argmax_last(t.value_ref(logits)).unwrap();
        let acc = preds.iter().zip(&labels).filter(|(p, l)| p == l).count() as f32 / 16.0;
        if step == 0 {
            first_acc = acc;
        }
        last_acc = acc;
        let grads: Vec<Tensor> = binds.iter().map(|v| t.grad(*v).unwrap()).collect();
        opt.step(model.params_mut(), &grads).unwrap();
        if log && (step % 10 == 0 || step + 1 == steps) {
            println!(
                "step {step:>3}  loss {:.4}  batch-acc {acc:.2}",
                t.value(loss).data()[0]
            );
        }
    }
    let params = model.params_mut();
    let refs: Vec<&Tensor> = params.iter().map(|p| &**p).collect();
    (first_acc, last_acc, hash_params(&refs))
}

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(60);
    println!("=== CNN run A ===");
    let (first, last, ha) = run(steps, true);
    println!("\n=== CNN run B ===");
    let (_, _, hb) = run(steps, false);
    println!("batch accuracy: {first:.2} -> {last:.2}");
    println!("hash A {}", &ha[..32]);
    println!("hash B {}", &hb[..32]);
    assert_eq!(ha, hb, "CNN training not reproducible!");
    println!("PASS — CNN training is bit-level reproducible");
}
