//! E2 demo — "train the same model on six platforms".
//!
//! The platform zoo simulates the paper's cross-platform hazards (SIMD
//! width, FMA, libm variant, size-dispatching kernels). Conventional
//! numerics diverge; RepDL numerics cannot (the platform knobs are not
//! even inputs to its kernels).
//!
//! ```sh
//! cargo run --release --offline --example cross_platform
//! ```

use repdl::baseline::PlatformProfile;
use repdl::coordinator::{compare_runs, NumericsMode, Trainer, TrainerConfig};

fn main() {
    let cfg = TrainerConfig { steps: 40, ..Default::default() };

    println!("training the MLP workload under 6 simulated platforms\n");
    println!(
        "{:<22} {:>12} {:>16} {:>10}",
        "platform", "final loss", "param hash[..8]", "div-step"
    );

    // conventional numerics: per-platform results
    let reference = Trainer::new(cfg, NumericsMode::Baseline(PlatformProfile::reference()))
        .run()
        .unwrap();
    for p in PlatformProfile::zoo() {
        let r = Trainer::new(cfg, NumericsMode::Baseline(p)).run().unwrap();
        let c = compare_runs(
            &reference.loss_curve,
            &r.loss_curve,
            &reference.param_hash,
            &r.param_hash,
        );
        println!(
            "baseline {:<13} {:>12.6} {:>16} {:>10}",
            p.name,
            r.loss_curve.last().unwrap(),
            &r.param_hash[..8],
            c.first_divergence.map(|s| s.to_string()).unwrap_or_else(|| "-".into())
        );
    }

    println!();
    // RepDL numerics: the profile is irrelevant — run it N times to show
    let mut hashes = std::collections::HashSet::new();
    for i in 0..PlatformProfile::zoo().len() {
        let r = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        println!(
            "repdl    run-{i}          {:>12.6} {:>16} {:>10}",
            r.loss_curve.last().unwrap(),
            &r.param_hash[..8],
            "-"
        );
        hashes.insert(r.param_hash);
    }
    println!(
        "\nbaseline produced multiple distinct states; RepDL produced {} distinct state(s)",
        hashes.len()
    );
    assert_eq!(hashes.len(), 1);
    println!("E2: PASS — cross-platform bitwise reproducibility holds");
}
