//! E3 demo — correct rounding in action (paper §2.2.1 / §3.2.1).
//!
//! Shows (1) two plausible libm implementations disagreeing on ordinary
//! inputs — the paper's glibc-vs-Intel example — while RepDL's `rexp`
//! matches the 320-bit oracle bit-for-bit; and (2) the ULP histogram of
//! each implementation against the oracle.
//!
//! ```sh
//! cargo run --release --offline --example correct_rounding_demo
//! ```

use repdl::baseline::{exp_variant, MathImpl};
use repdl::rnum::bigfloat::{BigFloat, PREC_ORACLE};
use repdl::rnum::fbits::ulp_diff;
use repdl::rnum::rexp;

fn oracle_exp(x: f32) -> f32 {
    BigFloat::from_f32(x, PREC_ORACLE).exp_bf().to_f32()
}

fn main() {
    println!("== the paper's §2.2.1 example: one function, two libms ==\n");
    println!(
        "{:>12} {:>14} {:>14} {:>14} {:>6}",
        "x", "glibc-like", "intel-like", "RepDL rexp", "agree?"
    );
    let mut shown = 0;
    let mut x = -10.0f32;
    while shown < 8 && x < 10.0 {
        let g = exp_variant(x, MathImpl::GlibcLike);
        let i = exp_variant(x, MathImpl::IntelLike);
        if g.to_bits() != i.to_bits() {
            println!(
                "{x:>12.5} {:>14e} {:>14e} {:>14e} {:>6}",
                g,
                i,
                rexp(x),
                "NO"
            );
            shown += 1;
        }
        x += 0.037;
    }

    println!("\n== ULP distance to the 320-bit oracle (20k sampled inputs) ==\n");
    let mut hist = [[0u32; 4]; 3]; // [impl][0,1,2,>2]
    let mut seed = 0x9e37u64;
    for _ in 0..20_000 {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        let x = (((seed >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 170.0; // [-85, 85]
        let want = oracle_exp(x);
        for (k, got) in [
            rexp(x),
            exp_variant(x, MathImpl::GlibcLike),
            exp_variant(x, MathImpl::IntelLike),
        ]
        .into_iter()
        .enumerate()
        {
            let d = ulp_diff(got, want).min(3) as usize;
            hist[k][d] += 1;
        }
    }
    println!(
        "{:<14} {:>8} {:>8} {:>8} {:>8}",
        "impl", "0 ulp", "1 ulp", "2 ulp", ">2 ulp"
    );
    for (name, row) in ["RepDL rexp", "glibc-like", "intel-like"].iter().zip(hist.iter()) {
        println!(
            "{name:<14} {:>8} {:>8} {:>8} {:>8}",
            row[0], row[1], row[2], row[3]
        );
    }
    assert_eq!(hist[0][1] + hist[0][2] + hist[0][3], 0, "rexp missed CR!");
    println!("\nE3: PASS — rexp is correctly rounded on every sampled input");
}
