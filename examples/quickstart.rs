//! Quickstart: RepDL in five minutes.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Shows the three things RepDL guarantees:
//! 1. correctly-rounded basic ops (identical bits everywhere),
//! 2. order-specified reductions (two named orders, each stable),
//! 3. bitwise-identical training runs.

use repdl::coordinator::{NumericsMode, Trainer, TrainerConfig};
use repdl::rnum::{rexp, rlog, rsin, sum_pairwise, sum_sequential};

fn main() {
    println!("== 1. correctly-rounded basic ops ==");
    for x in [0.5f32, 1.0, 2.0, -3.5] {
        println!(
            "rexp({x:>4}) = {:<12} bits {:#010x}",
            rexp(x),
            rexp(x).to_bits()
        );
    }
    println!("rlog(rexp(1.0)) = {}", rlog(rexp(1.0)));
    println!("rsin(3.14159265) = {:e}", rsin(std::f32::consts::PI));

    println!("\n== 2. reduction order is a specification ==");
    let xs: Vec<f32> = (0..10_000).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.1).collect();
    let seq = sum_sequential(&xs);
    let pair = sum_pairwise(&xs);
    println!("sum_sequential = {seq}  (bits {:#010x})", seq.to_bits());
    println!("sum_pairwise   = {pair}  (bits {:#010x})", pair.to_bits());
    println!("different APIs may differ in bits; each is stable across runs");

    println!("\n== 3. bitwise-reproducible training ==");
    let cfg = TrainerConfig { steps: 30, ..Default::default() };
    let a = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    let b = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    println!("run A final loss {:.6}, hash {}", a.loss_curve.last().unwrap(), &a.param_hash[..16]);
    println!("run B final loss {:.6}, hash {}", b.loss_curve.last().unwrap(), &b.param_hash[..16]);
    assert_eq!(a.param_hash, b.param_hash);
    println!("=> final model states are bit-identical");
}
