//! E7 demo — the dynamic-batching hazard (paper §2.2.2).
//!
//! The same 64 inference requests are replayed under batch sizes
//! 1/4/16/64. On a size-dispatching "platform" (how cuDNN/oneDNN pick
//! kernels), per-request bits change with batch composition. RepDL's
//! per-request reductions are independent of batch-mates — bit-invariant.
//!
//! ```sh
//! cargo run --release --offline --example serve_batch_invariance
//! ```

use repdl::baseline::PlatformProfile;
use repdl::coordinator::DeterministicServer;
use repdl::rng::uniform_tensor;
use repdl::tensor::{Tensor, WorkerPool};

fn main() {
    let d = 256;
    let n = 64;
    let w = uniform_tensor(&[d, 16], -0.3, 0.3, 5);
    let srv = DeterministicServer::new(w, 64).expect("rank-2 weights");
    let queue: Vec<Tensor> = (0..n)
        .map(|i| uniform_tensor(&[d], -1.0, 1.0, 100 + i as u64))
        .collect();

    println!("replaying {n} requests under batch sizes 1, 4, 16, 64\n");
    println!("{:<22} {:>18} {:>18}", "platform", "repdl mismatches", "baseline mismatches");
    for p in PlatformProfile::zoo() {
        let rep = srv
            .batch_invariance_report(&queue, &[1, 4, 16, 64], &p)
            .unwrap();
        println!(
            "{:<22} {:>14}/{:<3} {:>14}/{:<3}",
            p.name, rep.repro_mismatches, rep.requests, rep.baseline_mismatches, rep.requests
        );
        assert_eq!(rep.repro_mismatches, 0);
    }
    println!("\nE7: PASS — RepDL inference is batch-size invariant on every profile");

    // Pooled throughput: the same queue dispatched through persistent
    // worker pools of increasing size. Outputs are bit-identical for
    // every pool size (asserted) — only req/s changes.
    println!("\npooled serving throughput (bit-identical across pool sizes):");
    let reference = srv.process_repro(&queue).unwrap();
    for lanes in [1usize, 2, 4, 8] {
        let pool = WorkerPool::new(lanes);
        let outs = srv.process_repro_in(&pool, &queue).unwrap();
        for (a, b) in reference.iter().zip(outs.iter()) {
            assert!(a.bit_eq(b), "pool size changed serving bits!");
        }
        let t = srv.throughput_report(&pool, &queue, 5).unwrap();
        println!("  pool={lanes:<2} {:>12.0} req/s", t.req_per_s);
    }
}
